//! Pure-Rust LSMDS: iterative gradient descent on the raw stress (Eq. 1).
//!
//! This is (a) the reference implementation the PJRT `lsmds_steps` artifact
//! is cross-checked against, and (b) the fallback when no artifacts are
//! built. The default step size 1/(2N) on a centred configuration makes
//! each step exactly the unweighted SMACOF/Guttman transform, so descent is
//! monotone without tuning (the identity is proven in `smacof.rs` tests).
//!
//! Gradient evaluation is O(N^2 K) and row-parallel.

use crate::util::prng::Rng;
use crate::util::threadpool::{default_parallelism, parallel_for_chunks, SyncSlice};

use super::matrix::Matrix;
use super::stress::raw_stress;

#[derive(Clone, Debug)]
/// LSMDS solver settings (paper Sec. 2.1).
pub struct LsmdsConfig {
    /// Output dimension K.
    pub dim: usize,
    /// Maximum gradient-descent iterations.
    pub max_iters: usize,
    /// Stop when |sigma_prev - sigma| / sigma_prev falls below this.
    pub rel_tol: f64,
    /// Step size; `None` = 1/(2N) (SMACOF-equivalent, monotone).
    pub lr: Option<f64>,
    /// Scale of the random initial configuration.
    pub init_sigma: f32,
    /// Seed of the random initial configuration.
    pub seed: u64,
}

impl Default for LsmdsConfig {
    fn default() -> Self {
        Self {
            dim: 7, // paper Sec. 5.3
            max_iters: 500,
            rel_tol: 1e-6,
            lr: None,
            init_sigma: 1.0,
            seed: 7,
        }
    }
}

/// Result of an LSMDS run.
#[derive(Clone, Debug)]
pub struct LsmdsResult {
    /// N x K solution configuration.
    pub config: Matrix,
    /// Raw stress (Eq. 1) of the solution.
    pub raw_stress: f64,
    /// Normalised stress of the solution.
    pub normalized_stress: f64,
    /// Gradient iterations actually run.
    pub iters: usize,
}

/// Gradient of the raw stress at `x` (row-parallel). Returns (grad, sigma).
pub fn stress_gradient(x: &Matrix, delta: &Matrix) -> (Matrix, f64) {
    let n = x.rows;
    let k = x.cols;
    let mut grad = Matrix::zeros(n, k);
    let mut sres = vec![0.0f64; n];
    {
        let gslots = SyncSlice::new(&mut grad.data);
        let sslots = SyncSlice::new(&mut sres);
        parallel_for_chunks(n, 8, default_parallelism(), |start, end| {
            let mut gi = vec![0.0f64; k];
            for i in start..end {
                gi.iter_mut().for_each(|v| *v = 0.0);
                let xi = x.row(i);
                let mut s = 0.0f64;
                for j in 0..n {
                    if j == i {
                        continue;
                    }
                    let xj = x.row(j);
                    let d = crate::strdist::euclidean(xi, xj);
                    let delta_ij = delta.at(i, j) as f64;
                    let resid = d - delta_ij;
                    s += resid * resid;
                    let coef = if d > 1e-12 { resid / d } else { 0.0 };
                    for c in 0..k {
                        gi[c] += 2.0 * coef * (xi[c] as f64 - xj[c] as f64);
                    }
                }
                // SAFETY: row i belongs to exactly one chunk owner, so
                // sres[i] and grad row i are each written once.
                unsafe {
                    sslots.write(i, s);
                    for c in 0..k {
                        gslots.write(i * k + c, gi[c] as f32);
                    }
                }
            }
        });
    }
    (grad, 0.5 * sres.iter().sum::<f64>())
}

/// Width of the `j`-tile in the blocked gradient kernel: a tile of `x`
/// rows (`GRAD_TILE x K` f32, ~3.5 KB at K = 7) stays L1-resident while a
/// block of `GRAD_ROW_BLOCK` output rows sweeps it.
pub const GRAD_TILE: usize = 128;

/// Output rows accumulated per j-tile pass (the `parallel_for_chunks` work
/// item): each `x` tile loaded into cache is reused this many times.
pub const GRAD_ROW_BLOCK: usize = 16;

/// Cache-blocked, flat-`f32` gradient of the raw stress at `x`.
/// Returns (grad, sigma), like [`stress_gradient`].
///
/// This is the production kernel behind
/// [`ComputeBackend::lsmds_steps`](crate::runtime::ComputeBackend). Two
/// changes over the f64 oracle: (1) the `i`/`j` loops are interchanged
/// into `GRAD_ROW_BLOCK x GRAD_TILE` blocks, so each j-tile of `x` is
/// loaded once per row block instead of once per row; (2) the per-row
/// inner loop is the kernel-tier
/// [`stress_row_tile`](crate::runtime::simd::stress_row_tile) — a fused
/// distance + gradient pass over one stack-local diff vector that
/// accumulates the f32 squared distance in the canonical 8-lane tile
/// order (explicitly vectorised under `--kernel-tier simd`, identical
/// bits from the scalar tier). j-tiles advance in ascending order and
/// per-row stress still sums in `f64` — sigma stays comparable at any
/// N. Numerics therefore differ from [`stress_gradient`] only in the
/// last few bits of the f32 gradient; the parity contract
/// (`tests/backend_parity.rs`) holds the two within a scale-aware 1e-3.
pub fn stress_gradient_blocked(x: &Matrix, delta: &Matrix) -> (Matrix, f64) {
    let n = x.rows;
    let k = x.cols;
    let mut grad = Matrix::zeros(n, k);
    let mut sres = vec![0.0f64; n];
    {
        let gslots = SyncSlice::new(&mut grad.data);
        let sslots = SyncSlice::new(&mut sres);
        parallel_for_chunks(n, GRAD_ROW_BLOCK, default_parallelism(), |start, end| {
            let rows = end - start;
            let mut gi = vec![0.0f32; rows * k];
            let mut si = vec![0.0f64; rows];
            let mut diff = vec![0.0f32; k];
            let mut t0 = 0usize;
            while t0 < n {
                let t1 = (t0 + GRAD_TILE).min(n);
                for i in start..end {
                    let xi = x.row(i);
                    let drow = delta.row(i);
                    let gr = &mut gi[(i - start) * k..(i - start + 1) * k];
                    si[i - start] += crate::runtime::simd::stress_row_tile(
                        xi, x, t0, t1, i, drow, gr, &mut diff,
                    );
                }
                t0 = t1;
            }
            // SAFETY: rows start..end belong to this chunk owner alone,
            // so sres[i] and grad row i are each written exactly once.
            unsafe {
                for i in start..end {
                    sslots.write(i, si[i - start]);
                    for c in 0..k {
                        gslots.write(i * k + c, gi[(i - start) * k + c]);
                    }
                }
            }
        });
    }
    (grad, 0.5 * sres.iter().sum::<f64>())
}

/// Run LSMDS from a random (centred) initial configuration.
pub fn lsmds(delta: &Matrix, cfg: &LsmdsConfig) -> LsmdsResult {
    assert_eq!(delta.rows, delta.cols, "delta must be square");
    let n = delta.rows;
    let mut rng = Rng::new(cfg.seed);
    let mut x = Matrix::random_normal(&mut rng, n, cfg.dim, cfg.init_sigma);
    x.center_columns();
    lsmds_from(delta, x, cfg)
}

/// Run LSMDS from a caller-supplied initial configuration.
pub fn lsmds_from(delta: &Matrix, mut x: Matrix, cfg: &LsmdsConfig) -> LsmdsResult {
    let n = delta.rows;
    assert_eq!(x.rows, n);
    let lr = cfg.lr.unwrap_or(1.0 / (2.0 * n as f64));
    let mut prev_sigma = f64::INFINITY;
    let mut iters = 0;
    for it in 0..cfg.max_iters {
        let (grad, sigma) = stress_gradient(&x, delta);
        iters = it + 1;
        if sigma < 1e-10 {
            break; // absolute floor: relative checks are meaningless at ~0
        }
        if prev_sigma.is_finite() {
            let rel = (prev_sigma - sigma) / prev_sigma.max(1e-30);
            if rel.abs() < cfg.rel_tol {
                break;
            }
        }
        prev_sigma = sigma;
        for (xi, gi) in x.data.iter_mut().zip(grad.data.iter()) {
            *xi -= (lr * *gi as f64) as f32;
        }
    }
    let sigma = raw_stress(&x, delta);
    let norm = super::stress::normalized_stress(&x, delta);
    LsmdsResult { config: x, raw_stress: sigma, normalized_stress: norm, iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strdist::euclidean;

    fn realizable_delta(rng: &mut Rng, n: usize, k: usize) -> (Matrix, Matrix) {
        let x = Matrix::random_normal(rng, n, k, 1.0);
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                d.set(i, j, euclidean(x.row(i), x.row(j)) as f32);
            }
        }
        (x, d)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Rng::new(1);
        let (x, delta) = realizable_delta(&mut rng, 12, 3);
        // perturb x so the gradient is non-zero
        let mut xp = x.clone();
        for v in xp.data.iter_mut() {
            v.clone_from(&(*v + 0.1));
        }
        xp.set(0, 0, xp.at(0, 0) + 0.3);
        let (grad, _) = stress_gradient(&xp, &delta);
        let h = 1e-3f32;
        for &(r, c) in &[(0usize, 0usize), (3, 1), (11, 2)] {
            let mut plus = xp.clone();
            plus.set(r, c, plus.at(r, c) + h);
            let mut minus = xp.clone();
            minus.set(r, c, minus.at(r, c) - h);
            let fd = (raw_stress(&plus, &delta) - raw_stress(&minus, &delta))
                / (2.0 * h as f64);
            let g = grad.at(r, c) as f64;
            assert!(
                (fd - g).abs() < 2e-2 * (1.0 + g.abs()),
                "({r},{c}): fd={fd} grad={g}"
            );
        }
    }

    #[test]
    fn blocked_gradient_tracks_serial_oracle() {
        // non-realizable deltas so residuals (and the gradient) are large
        let mut rng = Rng::new(6);
        let x = Matrix::random_normal(&mut rng, 37, 3, 1.0);
        let (_, delta) = realizable_delta(&mut rng, 37, 3);
        let (gs, ss) = stress_gradient(&x, &delta);
        let (gb, sb) = stress_gradient_blocked(&x, &delta);
        let gmax = gs.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(
            gs.max_abs_diff(&gb) < 1e-3 * (1.0 + gmax),
            "grad diverges: {} (scale {gmax})",
            gs.max_abs_diff(&gb)
        );
        assert!((ss - sb).abs() < 1e-5 * (1.0 + ss), "sigma {ss} vs {sb}");
    }

    #[test]
    fn stress_descends_monotonically_with_default_lr() {
        let mut rng = Rng::new(2);
        let (_, delta) = realizable_delta(&mut rng, 30, 3);
        let mut x = Matrix::random_normal(&mut rng, 30, 3, 1.0);
        x.center_columns();
        let mut prev = f64::INFINITY;
        let lr = 1.0 / 60.0;
        for _ in 0..30 {
            let (grad, sigma) = stress_gradient(&x, &delta);
            assert!(sigma <= prev + 1e-9, "stress rose: {prev} -> {sigma}");
            prev = sigma;
            for (xi, gi) in x.data.iter_mut().zip(grad.data.iter()) {
                *xi -= (lr * *gi as f64) as f32;
            }
        }
    }

    #[test]
    fn recovers_realizable_configuration() {
        let mut rng = Rng::new(3);
        let (_, delta) = realizable_delta(&mut rng, 40, 2);
        let r = lsmds(&delta, &LsmdsConfig {
            dim: 2,
            max_iters: 2000,
            rel_tol: 1e-9,
            ..Default::default()
        });
        assert!(r.normalized_stress < 0.05, "sigma = {}", r.normalized_stress);
    }

    #[test]
    fn embedding_dimension_controls_quality() {
        // embedding 3-D distances into 1-D must be worse than into 3-D
        let mut rng = Rng::new(4);
        let (_, delta) = realizable_delta(&mut rng, 25, 3);
        let lo = lsmds(&delta, &LsmdsConfig { dim: 1, max_iters: 300, ..Default::default() });
        let hi = lsmds(&delta, &LsmdsConfig { dim: 3, max_iters: 300, ..Default::default() });
        assert!(hi.normalized_stress < lo.normalized_stress);
    }

    #[test]
    fn converges_early_on_tolerance() {
        let mut rng = Rng::new(5);
        let (x, delta) = realizable_delta(&mut rng, 20, 2);
        // start AT the solution: should stop almost immediately
        let r = lsmds_from(&delta, x, &LsmdsConfig {
            dim: 2,
            max_iters: 500,
            rel_tol: 1e-6,
            ..Default::default()
        });
        // at the optimum (stress ~ f32 noise) we must bail out quickly, not
        // chase relative fluctuations of ~0 for 500 iterations
        assert!(r.iters <= 10, "iters = {}", r.iters);
    }
}
