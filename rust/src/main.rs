//! `lmds-ose` launcher: the L3 coordinator CLI.
//!
//! Subcommands:
//!   generate   — emit Geco-style synthetic name data
//!   corpus     — write an out-of-core corpus file (binary object table)
//!   embed      — run the two-stage large-scale pipeline on generated data
//!                or, with `--corpus`, out-of-core against a corpus file
//!   serve      — start the streaming OSE service and run a query workload
//!   eval       — regenerate the paper's figures (fig1|fig23|fig4|headline|all)
//!   info       — artifact/manifest inventory
//!
//! Run `lmds-ose <cmd> --help` for per-command options.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use lmds_ose::coordinator::{
    embed_corpus, embed_dataset, BatcherConfig, DriftHook, Frame, NetServer,
    OseBackend, PipelineResult, QueryService, RefreshController, RunConfig, Server,
    ServerBuilder, ShardedServer,
};
use lmds_ose::data::source::{CorpusKind, CorpusWriter, ObjectTable, TableDelta};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::eval::figures;
use lmds_ose::eval::protocol::{self, Scale};
use lmds_ose::ose::OseMethod;
use lmds_ose::runtime::{default_artifact_dir, Backend, ComputeBackend};
use lmds_ose::util::cli::{usage, Args, OptSpec};
use lmds_ose::util::logging;

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_top_usage();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "generate" => cmd_generate(rest),
        "corpus" => cmd_corpus(rest),
        "embed" => cmd_embed(rest),
        "serve" => cmd_serve(rest),
        "eval" => cmd_eval(rest),
        "plot" => cmd_plot(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_top_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?} (try `lmds-ose help`)"),
    }
}

fn print_top_usage() {
    println!(
        "lmds-ose — high-performance out-of-sample embedding for LSMDS\n\n\
         USAGE: lmds-ose <command> [options]\n\n\
         COMMANDS:\n\
         \x20 generate   emit Geco-style synthetic name data\n\
         \x20 corpus     write an out-of-core corpus file (binary object table)\n\
         \x20 embed      two-stage pipeline: landmark LSMDS + OSE of the rest\n\
         \x20            (out-of-core with --corpus: data never leaves disk)\n\
         \x20 serve      streaming OSE service + synthetic query workload\n\
         \x20 eval       regenerate paper figures (fig1|fig23|fig4|headline|all)\n\
         \x20 plot       render results/*.json into SVG figures\n\
         \x20 info       artifact inventory\n"
    );
}

// ---------------------------------------------------------------------------

fn common_specs() -> Vec<OptSpec> {
    let opt = |name, help| OptSpec { name, help, takes_value: true, default: None };
    let flag = |name, help| OptSpec { name, help, takes_value: false, default: None };
    vec![
        opt("config", "JSON config file"),
        opt("dim", "embedding dimension K"),
        opt("landmarks", "number of landmarks L"),
        opt("landmark-method", "random|fps|maxmin"),
        opt("backend", "nn|opt"),
        opt("metric", "levenshtein|osa|jw|qgram"),
        opt("seed", "PRNG seed"),
        opt(
            "stream-chunk",
            "stream the OSE stage in chunks of this many rows (bounded memory; \
             0 = monolithic; with the nn backend this skips the bootstrap \
             training set — landmark rows only)",
        ),
        opt(
            "base-solver",
            "landmark base-MDS solver: monolithic|divide (divide = partitioned \
             parallel blocks + Procrustes stitching)",
        ),
        opt("base-blocks", "divide solver: number of blocks B"),
        opt("base-anchors", "divide solver: shared anchors A (0 = auto, sqrt(L))"),
        opt(
            "corpus",
            "out-of-core mode: embed a corpus file written by `lmds-ose corpus` \
             (dissimilarities evaluated at the storage layer; data never fully \
             materialises)",
        ),
        opt(
            "corpus-cache-mb",
            "out-of-core mode: pread block-cache budget in MiB (default 64; \
             ignored under mmap)",
        ),
        opt(
            "ose-steps",
            "opt backend: fixed majorization steps per embedding, early \
             stopping disabled (bit-reproducible across stream chunks; \
             0 = adaptive default)",
        ),
        opt(
            "kernel-tier",
            "compute kernel tier: auto|simd|scalar (auto = the \
             LMDS_KERNEL_TIER env var if set, else CPU detection; all \
             tiers are bit-identical)",
        ),
        opt(
            "query-k",
            "opt backend: majorize each query against only its k nearest \
             landmarks via the landmark small-world graph (0 = dense, \
             bit-identical to the classic all-landmark path)",
        ),
        opt("graph-m", "landmark graph: links per node per layer (min 2)"),
        opt(
            "graph-ef",
            "landmark graph: search beam width ef (min 1; construction \
             beam is max(64, ef))",
        ),
        flag("no-pjrt", "force the native compute backend (skip PJRT artifacts)"),
        flag("help", "show help"),
    ]
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    // Pin the kernel tier before any backend spins up; the default
    // "auto" still defers to LMDS_KERNEL_TIER / CPU detection.
    lmds_ose::runtime::simd::set_kernel_tier(cfg.tier());
    log::debug!(
        "kernel tier: {}",
        lmds_ose::runtime::simd::active_tier_name()
    );
    Ok(cfg)
}

/// Select the compute backend: PJRT artifacts when the `pjrt` feature is
/// compiled in, requested and loadable; the native backend otherwise.
fn select_backend(cfg: &RunConfig) -> Backend {
    #[cfg(feature = "pjrt")]
    {
        if cfg.use_pjrt {
            match Backend::pjrt(&default_artifact_dir()) {
                Ok(b) => return b,
                Err(e) => log::warn!(
                    "PJRT backend unavailable ({e:#}); using the native backend. \
                     Run `make artifacts` and link real xla bindings to enable it."
                ),
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    {
        if cfg.use_pjrt {
            log::debug!("built without the `pjrt` feature; using the native backend");
        }
    }
    Backend::native()
}

// ---------------------------------------------------------------------------

fn cmd_generate(argv: &[String]) -> Result<()> {
    let opt = |name, help, default| OptSpec { name, help, takes_value: true, default };
    let specs = vec![
        opt("n", "number of records", Some("1000")),
        opt("duplicate-rate", "fraction of corrupted duplicates", Some("0.0")),
        opt("seed", "PRNG seed", Some("40246")),
        opt("out", "output path (- = stdout)", Some("-")),
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("generate", "Generate Geco-style name data", &specs));
        return Ok(());
    }
    let n = args.usize("n")?;
    let mut geco = Geco::new(GecoConfig {
        seed: args.u64("seed")?,
        duplicate_rate: args.f64("duplicate-rate")?,
        ..Default::default()
    });
    let recs = geco.generate(n);
    let mut out = String::new();
    for r in &recs {
        out.push_str(&r.name);
        out.push('\n');
    }
    match args.str("out").as_str() {
        "-" => print!("{out}"),
        path => std::fs::write(path, out).context("writing output")?,
    }
    Ok(())
}

fn cmd_corpus(argv: &[String]) -> Result<()> {
    let opt = |name, help, default| OptSpec { name, help, takes_value: true, default };
    let specs = vec![
        opt("out", "corpus output path", None),
        opt("kind", "record layout: text|vec", Some("text")),
        opt("n", "number of records to generate", Some("100000")),
        opt("seed", "PRNG seed", Some("40246")),
        opt("from", "text: read records from this file (one per line) instead \
             of generating Geco names", None),
        opt("duplicate-rate", "text generation: fraction of corrupted duplicates", Some("0.0")),
        opt("dim", "vec: f32s per record", Some("8")),
        opt("clusters", "vec: number of Gaussian clusters", Some("8")),
        opt("spread", "vec: within-cluster standard deviation", Some("1.0")),
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!(
            "{}",
            usage("corpus", "Write an out-of-core corpus file (binary object table)", &specs)
        );
        return Ok(());
    }
    let out = args
        .get("out")
        .context("--out is required (where to write the corpus)")?;
    let out = std::path::Path::new(out);
    let seed = args.u64("seed")?;
    let summary = match args.str("kind").as_str() {
        "text" => {
            let mut w = CorpusWriter::create_text(out)?;
            match args.get("from") {
                Some(src) => {
                    // line-by-line: the input may be bigger than RAM,
                    // which is exactly the workload this feature serves
                    use std::io::BufRead;
                    let file = std::fs::File::open(src)
                        .with_context(|| format!("reading {src}"))?;
                    for line in std::io::BufReader::new(file).lines() {
                        w.push_text(&line.with_context(|| format!("reading {src}"))?)?;
                    }
                }
                None => {
                    let n = args.usize("n")?;
                    let mut geco = Geco::new(GecoConfig {
                        seed,
                        duplicate_rate: args.f64("duplicate-rate")?,
                        ..Default::default()
                    });
                    // streaming generator: uniqueness state spans the
                    // whole run, records go straight to disk
                    geco.generate_with(n, |r| w.push_text(&r.name))?;
                }
            }
            w.finish()?
        }
        "vec" => {
            let n = args.usize("n")?;
            let dim = args.usize("dim")?;
            let clusters = args.usize("clusters")?;
            let spread = args.f64("spread")?;
            let mut w = CorpusWriter::create_vectors(out, dim)?;
            let mut rng = lmds_ose::util::prng::Rng::new(seed);
            for batch_start in (0..n).step_by(8192) {
                let rows = lmds_ose::data::synthetic::gaussian_clusters(
                    &mut rng,
                    (n - batch_start).min(8192),
                    dim,
                    clusters,
                    spread,
                );
                for row in &rows {
                    w.push_vector(row)?;
                }
            }
            w.finish()?
        }
        other => anyhow::bail!("unknown corpus kind {other:?} (text|vec)"),
    };
    println!(
        "wrote {} ({} records, {:.1} MiB, {:?})",
        summary.path.display(),
        summary.count,
        summary.bytes as f64 / (1 << 20) as f64,
        summary.kind,
    );
    println!("embed it with: lmds-ose embed --corpus {}", summary.path.display());
    Ok(())
}

/// The out-of-core embed path: both pipeline stages run against the
/// on-disk object table; only landmarks, stream chunks and the N x K
/// output ever materialise.
fn cmd_embed_corpus(args: &Args, cfg: &RunConfig, path: &str) -> Result<()> {
    let table = ObjectTable::open(std::path::Path::new(path), cfg.corpus_cache_bytes())?;
    let metric_box = match table.kind() {
        CorpusKind::Text => Some(
            lmds_ose::strdist::string_metric_by_name(&cfg.metric)
                .context("unknown metric")?,
        ),
        CorpusKind::VecF32 => {
            if cfg.metric != RunConfig::default().metric {
                log::warn!(
                    "vector corpora use the euclidean metric; ignoring --metric {}",
                    cfg.metric
                );
            }
            None
        }
    };
    let euclid = lmds_ose::strdist::Euclidean;
    let source = match &metric_box {
        Some(m) => TableDelta::text(&table, m.as_ref())?,
        None => TableDelta::vectors(&table, &euclid)?,
    };
    let backend = select_backend(cfg);

    let t0 = Instant::now();
    let result = embed_corpus(&source, &cfg.pipeline(), &backend)?;
    let total = t0.elapsed().as_secs_f64();

    let n = table.len();
    println!("embedded {n} corpus records into {}D in {total:.2}s", cfg.dim);
    println!(
        "  corpus             : {path} ({:?}, {} storage)",
        table.kind(),
        table.storage_name()
    );
    if let Some(s) = table.cache_stats() {
        println!(
            "  row cache          : {} hits / {} misses / {} evictions, {:.1} MiB resident",
            s.hits,
            s.misses,
            s.evictions,
            s.resident_bytes as f64 / (1 << 20) as f64
        );
    }
    println!("  landmarks          : {} ({:?})", cfg.landmarks, cfg.landmark_method);
    println!("  base solver        : {:?}", cfg.base());
    println!("  compute backend    : {}", backend.name());
    println!("  ose method         : {:?} via {}", cfg.backend, result.method.name());
    let chunk = cfg.stream_chunk.unwrap_or(lmds_ose::ose::DEFAULT_STREAM_CHUNK);
    println!("  streaming          : {chunk}-row chunks read straight from the table");
    println!("  landmark stress    : {:.4}", result.landmark_stress);
    let t = &result.timings;
    println!(
        "  phases: select {:.2}s | delta_LL {:.2}s | lsmds {:.2}s | \
         train {:.2}s | delta_ML {:.2}s | ose {:.2}s",
        t.select_s, t.delta_ll_s, t.lsmds_s, t.train_s, t.delta_ml_s, t.ose_s
    );
    if let Some(out) = args.get("out") {
        write_corpus_coords(&table, &result, out)?;
        println!("  wrote coordinates to {out}");
    }
    Ok(())
}

/// Stream the coordinate table to `out` as JSON lines (text corpora get
/// their record echoed back; rows are re-read from the table one at a
/// time, so the object set still never materialises).
fn write_corpus_coords(table: &ObjectTable, result: &PipelineResult, out: &str) -> Result<()> {
    use lmds_ose::util::json::Json;
    use std::io::Write;
    let file = std::fs::File::create(out).with_context(|| format!("creating {out}"))?;
    let mut w = std::io::BufWriter::new(file);
    for i in 0..table.len() {
        let coords: Vec<String> =
            result.coords.row(i).iter().map(|v| format!("{v}")).collect();
        match table.kind() {
            CorpusKind::Text => {
                // corpus records are arbitrary user text: escape through
                // the JSON serialiser instead of interpolating raw
                let name = Json::Str(table.text_row(i)).to_string();
                writeln!(w, "{{\"name\":{name},\"coords\":[{}]}}", coords.join(","))?;
            }
            CorpusKind::VecF32 => {
                writeln!(w, "{{\"row\":{i},\"coords\":[{}]}}", coords.join(","))?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

fn cmd_embed(argv: &[String]) -> Result<()> {
    let mut specs = common_specs();
    specs.push(OptSpec {
        name: "n",
        help: "dataset size",
        takes_value: true,
        default: Some("2000"),
    });
    specs.push(OptSpec {
        name: "out",
        help: "coords output (JSON lines)",
        takes_value: true,
        default: None,
    });
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("embed", "Two-stage large-scale embedding pipeline", &specs));
        return Ok(());
    }
    let cfg = load_config(&args)?;
    if let Some(path) = cfg.corpus.clone() {
        return cmd_embed_corpus(&args, &cfg, &path);
    }
    let n = args.usize("n")?;

    let mut geco = Geco::new(GecoConfig { seed: cfg.seed, ..Default::default() });
    let names = geco.generate_unique(n);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let metric = lmds_ose::strdist::string_metric_by_name(&cfg.metric)
        .context("unknown metric")?;

    let backend = select_backend(&cfg);

    let t0 = Instant::now();
    let result = embed_dataset(&objs, metric.as_ref(), &cfg.pipeline(), &backend)?;
    let total = t0.elapsed().as_secs_f64();

    println!("embedded {n} objects into {}D in {total:.2}s", cfg.dim);
    println!("  landmarks          : {} ({:?})", cfg.landmarks, cfg.landmark_method);
    println!("  base solver        : {:?}", cfg.base());
    println!("  compute backend    : {}", backend.name());
    println!("  ose method         : {:?} via {}", cfg.backend, result.method.name());
    if let Some(chunk) = cfg.stream_chunk {
        println!("  streaming          : {chunk}-row chunks (bounded memory, overlapped)");
    }
    println!("  landmark stress    : {:.4}", result.landmark_stress);
    let t = &result.timings;
    println!(
        "  phases: select {:.2}s | delta_LL {:.2}s | lsmds {:.2}s | \
         train {:.2}s | delta_ML {:.2}s | ose {:.2}s",
        t.select_s, t.delta_ll_s, t.lsmds_s, t.train_s, t.delta_ml_s, t.ose_s
    );
    if let Some(path) = args.get("out") {
        let mut out = String::new();
        for (i, name) in names.iter().enumerate() {
            let coords: Vec<String> = result
                .coords
                .row(i)
                .iter()
                .map(|v| format!("{v}"))
                .collect();
            out.push_str(&format!(
                "{{\"name\":\"{name}\",\"coords\":[{}]}}\n",
                coords.join(",")
            ));
        }
        std::fs::write(path, out)?;
        println!("  wrote coordinates to {}", args.str("out"));
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let mut specs = common_specs();
    let opt = |name, help, default| OptSpec { name, help, takes_value: true, default };
    specs.push(opt("n", "landmark-training dataset size", Some("2000")));
    specs.push(opt("queries", "number of workload queries", Some("10000")));
    specs.push(opt("clients", "concurrent client threads", Some("4")));
    specs.push(opt(
        "replicas",
        "OSE executor replicas in the serving pool (panic-isolated, restartable)",
        None,
    ));
    specs.push(opt(
        "drift-window",
        "drift-monitor sliding window in queries (0 = disabled)",
        None,
    ));
    specs.push(opt(
        "shards",
        "serving shards (1 = classic unsharded; >1 partitions the landmarks \
         and quorum-reduces per-shard partial embeddings)",
        None,
    ));
    specs.push(opt(
        "listen",
        "serve the binary wire protocol over TCP at host:port (port 0 = \
         ephemeral); the workload then runs over real sockets",
        None,
    ));
    specs.push(opt("max-connections", "front door: connection limit", None));
    specs.push(opt(
        "max-in-flight",
        "front door: in-flight query cap before load shedding",
        None,
    ));
    specs.push(OptSpec {
        name: "refresh",
        help: "close the streaming loop: buffer recent queries and hot \
               re-embed the landmark base when the drift monitor fires \
               (needs --drift-window > 0, the opt backend and --shards 1)",
        takes_value: false,
        default: None,
    });
    specs.push(opt(
        "refresh-cooldown",
        "minimum milliseconds between two drift-triggered refreshes",
        None,
    ));
    specs.push(opt(
        "ingest-buffer",
        "recent-query buffer capacity feeding refresh ingestion (min 1)",
        None,
    ));
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("serve", "Streaming OSE service + query workload", &specs));
        return Ok(());
    }
    let cfg = load_config(&args)?;
    let n = args.usize("n")?;
    let queries = args.usize("queries")?;
    let clients = args.usize("clients")?.max(1);

    // build the service state with the pipeline
    let mut geco = Geco::new(GecoConfig { seed: cfg.seed, ..Default::default() });
    let names = geco.generate_unique(n);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let metric = lmds_ose::strdist::string_metric_by_name(&cfg.metric)
        .context("unknown metric")?;
    let backend = select_backend(&cfg);
    let result = embed_dataset(&objs, metric.as_ref(), &cfg.pipeline(), &backend)?;
    let landmark_names: Vec<String> = result
        .landmark_idx
        .iter()
        .map(|&i| names[i].clone())
        .collect();

    let metric_arc: Arc<dyn lmds_ose::strdist::Dissimilarity<str> + Send + Sync> =
        Arc::new(lmds_ose::strdist::Levenshtein);
    let mut builder = ServerBuilder::strings(
        landmark_names,
        metric_arc,
        result.factory.clone(),
    )
    .batcher(BatcherConfig { frontend_threads: clients, ..cfg.batcher() })
    .landmark_config(result.landmark_config.clone())
    .backend(backend.clone());
    if let Some(dcfg) = cfg.drift() {
        builder = builder.drift(DriftHook {
            landmark_config: result.landmark_config.clone(),
            cfg: dcfg,
        });
    }

    // either serving topology exposes the same QueryService surface
    enum Serving {
        Flat(Server<str>),
        Sharded(ShardedServer<str>),
    }
    let (serving, service, flat_handle): (
        Serving,
        Arc<dyn QueryService>,
        Option<lmds_ose::coordinator::ServerHandle<str>>,
    ) = if cfg.shards > 1 {
        let s = builder
            .shards(cfg.shard())
            .build_sharded()
            .map_err(|e| anyhow::anyhow!("starting sharded server: {e}"))?;
        let h = s.handle();
        log::info!("sharded serving: {} shards", h.shards());
        (Serving::Sharded(s), Arc::new(h), None)
    } else {
        let s = builder
            .build()
            .map_err(|e| anyhow::anyhow!("starting server: {e}"))?;
        let h = s.handle();
        (Serving::Flat(s), Arc::new(h.clone()), Some(h))
    };
    let metrics = service.metrics();

    // drift-triggered hot refresh: buffer live queries, re-solve the
    // landmark base in a shadow generation and swap it in when the
    // drift monitor fires
    let refresher = start_refresher(&cfg, flat_handle, &result, &backend, &names)?;

    // synthetic query workload (corrupted copies of known names = realistic
    // near-duplicate queries), in-process or over real loopback sockets
    log::info!("running {queries} queries from {clients} client threads");
    let t0 = Instant::now();
    match cfg.net() {
        Some(netcfg) => {
            let front = NetServer::start(Arc::clone(&service), netcfg)
                .map_err(|e| anyhow::anyhow!("starting network front door: {e}"))?;
            let addr = front.local_addr();
            println!("serving the wire protocol on {addr}");
            run_net_workload(addr, queries, clients, &names)?;
            front.shutdown();
        }
        None => run_local_workload(&service, queries, clients, &names),
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = metrics.snapshot();
    println!("workload done in {wall:.2}s  ({:.0} queries/s)", snap.completed as f64 / wall);
    println!("  {}", snap.report());
    if let Some((ctl, ingest_corpus)) = refresher {
        if let Some(r) = ctl.last_report() {
            println!(
                "  refresh: now generation {} ({} queries ingested, landmark \
                 stress {:.4}, drain {}ms)",
                r.generation,
                r.ingested,
                r.landmark_stress,
                r.swap_drain.as_millis()
            );
        }
        ctl.stop();
        let _ = std::fs::remove_file(&ingest_corpus);
    }
    drop(service);
    match serving {
        Serving::Flat(s) => s.shutdown(),
        Serving::Sharded(s) => s.shutdown(),
    }
    Ok(())
}

/// Arm the drift-triggered refresh loop when `--refresh` asked for it and
/// the topology supports it (unsharded, opt backend, drift monitor on).
///
/// The serve workload embeds generated names rather than an on-disk
/// corpus, so the controller gets a temporary corpus written in the same
/// row order — `landmark_idx` then addresses it directly, and ingested
/// queries append behind the original rows. The temp file is removed
/// after the controller stops.
fn start_refresher(
    cfg: &RunConfig,
    handle: Option<lmds_ose::coordinator::ServerHandle<str>>,
    result: &PipelineResult,
    backend: &Backend,
    names: &[String],
) -> Result<Option<(RefreshController, std::path::PathBuf)>> {
    let Some(rcfg) = cfg.refresh_cfg() else {
        if cfg.refresh {
            log::warn!(
                "--refresh needs the drift monitor; pass --drift-window > 0"
            );
        }
        return Ok(None);
    };
    let Some(handle) = handle else {
        log::warn!("--refresh supports unsharded serving only; pass --shards 1");
        return Ok(None);
    };
    if cfg.backend != OseBackend::Opt {
        log::warn!(
            "--refresh supports the opt OSE backend only (nn needs a retrain)"
        );
        return Ok(None);
    }
    let path = std::env::temp_dir()
        .join(format!("lmds-serve-ingest-{}.corpus", std::process::id()));
    let mut w = CorpusWriter::create_text(&path)
        .context("writing the refresh ingest corpus")?;
    for name in names {
        w.push_text(name)?;
    }
    w.finish()?;
    let ctl = RefreshController::start(
        handle,
        path.clone(),
        cfg.pipeline(),
        backend.clone(),
        result.landmark_idx.clone(),
        result.landmark_config.clone(),
        rcfg.clone(),
    )
    .context("starting the refresh controller")?;
    log::info!(
        "refresh armed: cooldown {}ms, ingest buffer {} (corpus {})",
        rcfg.cooldown.as_millis(),
        rcfg.ingest_buffer,
        path.display()
    );
    Ok(Some((ctl, path)))
}

/// In-process serve workload: pipelined submissions straight into the
/// handle, 64 in flight per client.
fn run_local_workload(
    service: &Arc<dyn QueryService>,
    queries: usize,
    clients: usize,
    names: &[String],
) {
    std::thread::scope(|scope| {
        for c in 0..clients {
            let service = Arc::clone(service);
            scope.spawn(move || {
                let mut geco = Geco::new(GecoConfig {
                    seed: 0xc11 + c as u64,
                    ..Default::default()
                });
                let per = queries / clients;
                let mut pending = Vec::with_capacity(64);
                for q in 0..per {
                    let base = &names[(q * 31 + c) % names.len()];
                    let query = geco.corrupt(base);
                    let (tx, rx) = std::sync::mpsc::channel();
                    service.submit_text(
                        query,
                        Box::new(move |r| {
                            let _ = tx.send(r);
                        }),
                    );
                    pending.push(rx);
                    if pending.len() >= 64 {
                        for rx in pending.drain(..) {
                            let _ = rx.recv();
                        }
                    }
                }
                for rx in pending {
                    let _ = rx.recv();
                }
            });
        }
    });
}

/// Wire-protocol serve workload: each client opens a TCP connection and
/// pipelines QueryText frames, 64 in flight.
fn run_net_workload(
    addr: std::net::SocketAddr,
    queries: usize,
    clients: usize,
    names: &[String],
) -> Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};

    use lmds_ose::coordinator::proto::{read_frame, write_frame};

    let degraded_total = AtomicU64::new(0);
    let error_total = AtomicU64::new(0);
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for c in 0..clients {
            let degraded_total = &degraded_total;
            let error_total = &error_total;
            joins.push(scope.spawn(move || -> Result<()> {
                let mut stream = std::net::TcpStream::connect(addr)
                    .context("connecting to the front door")?;
                let mut geco = Geco::new(GecoConfig {
                    seed: 0xc11 + c as u64,
                    ..Default::default()
                });
                let mut read_one = |stream: &mut std::net::TcpStream| -> Result<()> {
                    match read_frame(stream).context("reading a reply frame")? {
                        Frame::Result { degraded, .. } => {
                            if degraded {
                                degraded_total.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Frame::Error { .. } => {
                            error_total.fetch_add(1, Ordering::Relaxed);
                        }
                        other => anyhow::bail!("unexpected reply frame {other:?}"),
                    }
                    Ok(())
                };
                let per = queries / clients;
                let mut inflight = 0usize;
                for q in 0..per {
                    let base = &names[(q * 31 + c) % names.len()];
                    let query = geco.corrupt(base);
                    write_frame(
                        &mut stream,
                        &Frame::QueryText { id: q as u64, text: query },
                    )
                    .context("writing a query frame")?;
                    inflight += 1;
                    if inflight >= 64 {
                        read_one(&mut stream)?;
                        inflight -= 1;
                    }
                }
                while inflight > 0 {
                    read_one(&mut stream)?;
                    inflight -= 1;
                }
                Ok(())
            }));
        }
        for j in joins {
            j.join().expect("client thread panicked")?;
        }
        Ok(())
    })?;
    let degraded = degraded_total.into_inner();
    let errors = error_total.into_inner();
    if degraded > 0 || errors > 0 {
        println!("  degraded replies: {degraded}  error replies: {errors}");
    }
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let mut specs = common_specs();
    specs.push(OptSpec {
        name: "scale",
        help: "smoke|small|paper",
        takes_value: true,
        default: Some("small"),
    });
    specs.push(OptSpec {
        name: "epochs",
        help: "NN training epochs",
        takes_value: true,
        default: Some("60"),
    });
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("eval", "Regenerate the paper's figures", &specs));
        return Ok(());
    }
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let scale = Scale::from_name(&args.str("scale"))
        .with_context(|| format!("unknown scale {:?}", args.str("scale")))?;
    let epochs = args.usize("epochs")?;
    let cfg = load_config(&args)?;
    let backend = select_backend(&cfg);

    let dim = if args.get("dim").is_some() { args.usize("dim")? } else { 7 };
    let data = protocol::load_or_build(scale, dim, &backend)?;

    match which {
        "fig1" => {
            figures::fig1(&data, &backend, epochs)?;
        }
        "fig2" | "fig3" | "fig23" => {
            figures::fig23(&data, &backend, epochs)?;
        }
        "fig4" => {
            figures::fig4(&data, &backend, epochs)?;
        }
        "headline" => figures::headline(&data, &backend, epochs)?,
        "ablations" => {
            let l = data.scale.sweep()[1];
            lmds_ose::eval::ablations::landmark_methods(&data, &backend, l)?;
            lmds_ose::eval::ablations::ose_baselines(&data, &backend, l, epochs)?;
            lmds_ose::eval::ablations::step_size(&data, l)?;
            lmds_ose::eval::ablations::nn_hidden(&data, l, epochs)?;
        }
        "all" => {
            figures::fig1(&data, &backend, epochs)?;
            figures::fig23(&data, &backend, epochs)?;
            figures::fig4(&data, &backend, epochs)?;
            figures::headline(&data, &backend, epochs)?;
        }
        other => anyhow::bail!(
            "unknown figure {other:?} (fig1|fig23|fig4|headline|ablations|all)"
        ),
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let specs = vec![OptSpec {
        name: "help",
        help: "show help",
        takes_value: false,
        default: None,
    }];
    let _ = Args::parse(argv, &specs)?;
    let dir = default_artifact_dir();
    println!(
        "compute backends: native (always){}",
        if cfg!(feature = "pjrt") {
            ", pjrt (compiled in)"
        } else {
            " — rebuild with --features pjrt for artifacts"
        }
    );
    println!("artifact dir: {dir:?}");
    match lmds_ose::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("k_dim={} hidden={:?} artifacts={}", m.k_dim, m.hidden, m.artifacts.len());
            let mut by_graph: std::collections::BTreeMap<&str, usize> = Default::default();
            for a in &m.artifacts {
                *by_graph.entry(a.graph.as_str()).or_default() += 1;
            }
            for (g, c) in by_graph {
                println!("  {g:<16} {c} variants");
            }
        }
        Err(e) => println!("no manifest: {e:#} (run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_plot(argv: &[String]) -> Result<()> {
    use lmds_ose::util::json::Json;
    use lmds_ose::util::svgplot::Chart;
    let specs = vec![
        OptSpec {
            name: "scale",
            help: "smoke|small|paper",
            takes_value: true,
            default: Some("small"),
        },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("plot", "Render results/*.json into SVG figures", &specs));
        return Ok(());
    }
    let scale = args.str("scale");
    let dir = protocol::results_dir();

    // Figure 1: Err(m) vs L
    let fig1 = dir.join(format!("fig1_{scale}.json"));
    if let Ok(text) = std::fs::read_to_string(&fig1) {
        let v = Json::parse(&text)?;
        let rows = v.get("rows").and_then(Json::as_arr).context("rows")?;
        let mut c = Chart::line(
            &format!("Figure 1 — Err(m) vs L ({scale})"),
            "landmarks L",
            "Err(m)",
        );
        let take = |key: &str| -> Vec<(f64, f64)> {
            rows.iter()
                .filter_map(|r| {
                    Some((r.get("L")?.as_f64()?, r.get(key)?.as_f64()?))
                })
                .collect()
        };
        c.add("optimisation", "#d62728", take("err_opt"));
        c.add("neural network", "#1f77b4", take("err_nn"));
        let out = dir.join(format!("fig1_{scale}.svg"));
        std::fs::write(&out, c.render())?;
        println!("wrote {out:?}");
    }

    // Figure 4: RT vs L (log y)
    let fig4 = dir.join(format!("fig4_{scale}.json"));
    if let Ok(text) = std::fs::read_to_string(&fig4) {
        let v = Json::parse(&text)?;
        let rows = v.get("rows").and_then(Json::as_arr).context("rows")?;
        let mut c = Chart::line(
            &format!("Figure 4 — RT per point vs L ({scale})"),
            "landmarks L",
            "seconds per point (log)",
        );
        c.log_y = true;
        let take = |key: &str| -> Vec<(f64, f64)> {
            rows.iter()
                .filter_map(|r| {
                    Some((r.get("L")?.as_f64()?, r.get(key)?.as_f64()?))
                })
                .collect()
        };
        c.add("optimisation", "#d62728", take("rt_opt_s"));
        c.add("neural network", "#1f77b4", take("rt_nn_s"));
        let out = dir.join(format!("fig4_{scale}.svg"));
        std::fs::write(&out, c.render())?;
        println!("wrote {out:?}");
    }

    // Figure 2: per-point scatter nn vs opt
    let fig23 = dir.join(format!("fig23_{scale}.json"));
    if let Ok(text) = std::fs::read_to_string(&fig23) {
        let v = Json::parse(&text)?;
        for result in v.get("results").and_then(Json::as_arr).unwrap_or(&[]) {
            let l = result.get("L").and_then(Json::as_usize).unwrap_or(0);
            let opt: Vec<f64> = result
                .get("perr_opt")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default();
            let nn: Vec<f64> = result
                .get("perr_nn")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default();
            let mut c = Chart::line(
                &format!("Figure 2 — PErr scatter, L={l} ({scale})"),
                "PErr optimisation",
                "PErr neural network",
            );
            c.scatter = true;
            c.add(
                "points",
                "#1f77b4",
                opt.iter().copied().zip(nn.iter().copied()).collect(),
            );
            // y = x reference line
            let hi = opt
                .iter()
                .chain(nn.iter())
                .cloned()
                .fold(0.0f64, f64::max)
                .max(1e-9);
            c.scatter = true;
            let mut yx = Chart::line("", "", "");
            let _ = yx; // keep scatter; draw y=x as a 2-point series
            c.series.push(lmds_ose::util::svgplot::Series {
                label: "y = x".into(),
                points: vec![(0.0, 0.0), (hi, hi)],
                color: "#999999",
            });
            c.scatter = false; // lines allowed again so y=x renders
            let out = dir.join(format!("fig2_L{l}_{scale}.svg"));
            std::fs::write(&out, c.render())?;
            println!("wrote {out:?}");
        }
    }
    Ok(())
}

