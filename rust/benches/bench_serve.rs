//! Serving-core bench: throughput scaling of the replicated executor pool
//! (1 vs 4 replicas) on the MLP OSE method, plus tail latency read from
//! the bounded log-bucketed histograms. Writes a machine-readable JSON
//! report for the CI perf trajectory.
//!
//!     cargo bench --bench bench_serve
//!
//! Env knobs:
//!   LMDS_BENCH_QUICK=1        smaller query volume (CI smoke)
//!   LMDS_BENCH_JSON=path.json where to write the report
//!                             (default BENCH_pr3.json in the CWD)
//!
//! The load bypasses the frontend (delta requests with precomputed rows) so
//! the numbers isolate the dispatch-queue + executor-pool path: small
//! batches (max_batch = 8) keep each embed call on one core, which is the
//! regime where replica-level parallelism is the only scaling lever.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lmds_ose::coordinator::methods::BackendNn;
use lmds_ose::coordinator::{BatcherConfig, Request, ServerBuilder, Snapshot};
use lmds_ose::nn::{MlpParams, MlpShape};
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Levenshtein;
use lmds_ose::util::json::Json;
use lmds_ose::util::prng::Rng;

const L: usize = 300;
const MAX_BATCH: usize = 8;

fn run_load(
    params: &MlpParams,
    replicas: usize,
    queries: usize,
    clients: usize,
) -> (f64, Snapshot) {
    let landmarks: Vec<String> = (0..L).map(|i| format!("landmark{i:03}")).collect();
    let server = ServerBuilder::strings(
        landmarks,
        Arc::new(Levenshtein),
        BackendNn::replica_factory(Backend::native(), params.clone()),
    )
    .batcher(BatcherConfig {
        max_batch: MAX_BATCH,
        max_delay: Duration::from_micros(200),
        queue_cap: 4096,
        frontend_threads: 1,
        replicas,
    })
    .build()
    .expect("valid server configuration");
    let h = server.handle();
    let mut rng = Rng::new(0x5e55);
    let delta: Vec<f32> = (0..L).map(|_| rng.next_f32() * 5.0).collect();

    // warm the executors
    for _ in 0..64 {
        h.submit(Request::delta(delta.clone())).recv().unwrap();
    }
    let warm = h.metrics.snapshot().completed;

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..clients {
            let h = h.clone();
            let delta = delta.clone();
            scope.spawn(move || {
                let per = queries / clients;
                let mut pending = VecDeque::with_capacity(64);
                for _ in 0..per {
                    pending.push_back(h.submit(Request::delta(delta.clone())));
                    if pending.len() >= 64 {
                        pending.pop_front().unwrap().recv().unwrap();
                    }
                }
                for t in pending {
                    t.recv().unwrap();
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let snap = h.metrics.snapshot();
    assert_eq!(snap.failed, 0, "bench load must not fail");
    let served = snap.completed - warm;
    drop(h);
    server.shutdown();
    (served as f64 / wall, snap)
}

fn main() {
    lmds_ose::util::logging::init();
    let quick = std::env::var("LMDS_BENCH_QUICK").is_ok();
    let queries = if quick { 4_000 } else { 24_000 };
    let clients = 4;

    let mut rng = Rng::new(1);
    let params = MlpParams::init(
        &MlpShape { input: L, hidden: [256, 128, 64], output: 7 },
        &mut rng,
    );

    println!(
        "== serving core: replicated executor pool (MLP L={L}, \
         max_batch={MAX_BATCH}, {queries} queries, {clients} clients) =="
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut qps_by_replicas = Vec::new();
    for replicas in [1usize, 4] {
        let (qps, snap) = run_load(&params, replicas, queries, clients);
        println!(
            "replicas={replicas}: {qps:6.0} queries/s | p50 {:.3}ms p99 {:.3}ms \
             | mean batch {:.1} | {}",
            snap.p50_s * 1e3,
            snap.p99_s * 1e3,
            snap.mean_batch_size,
            snap.report()
        );
        rows.push(Json::obj(vec![
            ("replicas", Json::Num(replicas as f64)),
            ("qps", Json::Num(qps)),
            ("p50_s", Json::Num(snap.p50_s)),
            ("p95_s", Json::Num(snap.p95_s)),
            ("p99_s", Json::Num(snap.p99_s)),
            ("mean_batch", Json::Num(snap.mean_batch_size)),
            ("batches", Json::Num(snap.batches as f64)),
            ("metrics_footprint", Json::Num(snap.metrics_footprint as f64)),
        ]));
        qps_by_replicas.push(qps);
    }
    let speedup = qps_by_replicas[1] / qps_by_replicas[0];
    println!("4-replica speedup over 1 replica: {speedup:.2}x");

    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_serve".into())),
        ("backend", Json::Str("native".into())),
        ("method", Json::Str("nn".into())),
        ("max_batch", Json::Num(MAX_BATCH as f64)),
        ("queries", Json::Num(queries as f64)),
        ("clients", Json::Num(clients as f64)),
        ("results", Json::Arr(rows)),
        ("speedup_4v1", Json::Num(speedup)),
    ]);
    let path = std::env::var("LMDS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_pr3.json".to_string());
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote serving bench report to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
