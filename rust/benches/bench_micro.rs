//! Micro benchmarks of every hot-path component (custom harness — the
//! image vendors no criterion). Prints one line per subject.
//!
//!     cargo bench --bench bench_micro

use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::{cross_matrix, full_matrix};
use lmds_ose::mds::lsmds::stress_gradient;
use lmds_ose::mds::Matrix;
use lmds_ose::nn::{forward, MlpParams, MlpShape};
use lmds_ose::ose::{embed_point, OseOptConfig};
use lmds_ose::runtime::{default_artifact_dir, OwnedArg, RuntimeThread};
use lmds_ose::strdist::{jaro_winkler_distance, levenshtein, levenshtein_dp, qgram_distance, Levenshtein};
use lmds_ose::util::bench::{bench, BenchConfig};
use lmds_ose::util::prng::Rng;

fn main() {
    lmds_ose::util::logging::init();
    let cfg = BenchConfig::default();
    let quick = BenchConfig {
        measure: std::time::Duration::from_millis(500),
        ..BenchConfig::default()
    };
    let mut rng = Rng::new(1);
    let mut geco = Geco::new(GecoConfig { seed: 2, ..Default::default() });
    let names = geco.generate_unique(2000);

    println!("== string distances ==");
    let mut i = 0usize;
    let r = bench("levenshtein/myers (name pair)", &cfg, || {
        i = (i + 1) % 1999;
        levenshtein(&names[i], &names[i + 1])
    });
    println!("{}  ({:.1}M pairs/s)", r.report(), r.throughput(1) / 1e6);
    let r2 = bench("levenshtein/dp (name pair)", &cfg, || {
        i = (i + 1) % 1999;
        levenshtein_dp(&names[i], &names[i + 1])
    });
    println!("{}  (myers speedup {:.1}x)", r2.report(), r2.median_s / r.median_s);
    let r = bench("jaro-winkler (name pair)", &quick, || {
        i = (i + 1) % 1999;
        jaro_winkler_distance(&names[i], &names[i + 1])
    });
    println!("{}", r.report());
    let r = bench("qgram2 (name pair)", &quick, || {
        i = (i + 1) % 1999;
        qgram_distance(&names[i], &names[i + 1], 2)
    });
    println!("{}", r.report());

    println!("\n== dissimilarity engine ==");
    let sub: Vec<&str> = names[..500].iter().map(|s| s.as_str()).collect();
    let r = bench("full_matrix 500x500 (parallel)", &BenchConfig::heavy(), || {
        full_matrix(&sub, &Levenshtein)
    });
    println!("{}  ({:.1}M dists/s)", r.report(), r.throughput(500 * 499 / 2) / 1e6);
    let rows: Vec<&str> = names[500..756].iter().map(|s| s.as_str()).collect();
    let r = bench("cross_matrix 256x500", &BenchConfig::heavy(), || {
        cross_matrix(&rows, &sub, &Levenshtein)
    });
    println!("{}  ({:.1}M dists/s)", r.report(), r.throughput(256 * 500) / 1e6);

    println!("\n== pure-Rust numeric kernels ==");
    let x = Matrix::random_normal(&mut rng, 300, 7, 1.0);
    let delta = {
        let mut d = Matrix::zeros(300, 300);
        for i in 0..300 {
            for j in 0..300 {
                d.set(i, j, lmds_ose::strdist::euclidean(x.row(i), x.row(j)) as f32);
            }
        }
        d
    };
    let r = bench("stress_gradient N=300 K=7", &quick, || {
        stress_gradient(&x, &delta)
    });
    println!("{}", r.report());
    let lm = Matrix::random_normal(&mut rng, 300, 7, 1.0);
    let dl: Vec<f32> = (0..300).map(|_| rng.next_f32() * 5.0).collect();
    let r = bench("ose embed_point L=300 (rust)", &quick, || {
        embed_point(&lm, &dl, None, &OseOptConfig::default())
    });
    println!("{}", r.report());
    let params = MlpParams::init(
        &MlpShape { input: 300, hidden: [256, 128, 64], output: 7 },
        &mut rng,
    );
    let q = Matrix::from_vec(1, 300, dl.clone());
    let r = bench("mlp forward B=1 L=300 (rust)", &quick, || {
        forward(&params, &q)
    });
    println!("{}", r.report());

    // PJRT exec latency (needs artifacts)
    match RuntimeThread::spawn(&default_artifact_dir()) {
        Ok(rt) => {
            println!("\n== PJRT execution (L=300, paper-scale artifacts) ==");
            let h = rt.handle();
            let flat = params.flatten();
            for b in [1usize, 64, 256] {
                let Some(spec) = h
                    .manifest()
                    .find("mlp_fwd", &[("L", 300), ("B", b)])
                    .cloned()
                else {
                    continue;
                };
                // bind weights once (positions 1..=8)
                let mut bind_args = Vec::new();
                for (i, p) in flat.iter().enumerate() {
                    let sh = &spec.args[1 + i].shape;
                    bind_args.push((
                        1 + i,
                        if sh.len() == 2 {
                            OwnedArg::Mat(Matrix::from_vec(sh[0], sh[1], p.clone()))
                        } else {
                            OwnedArg::Vec1(p.clone())
                        },
                    ));
                }
                h.bind("bench-w", bind_args).unwrap();
                let input = Matrix::from_vec(
                    b,
                    300,
                    (0..b * 300).map(|_| rng.next_f32() * 5.0).collect(),
                );
                let r = bench(&format!("mlp_fwd exec B={b} (bound weights)"), &quick, || {
                    h.execute_bound(&spec.name, "bench-w", vec![(0, OwnedArg::Mat(input.clone()))])
                        .unwrap()
                });
                println!("{}  ({:.0} pts/s)", r.report(), r.throughput(b));
            }
            if let Some(spec) = h.manifest().find("ose_opt", &[("L", 300), ("B", 64)]) {
                let spec = spec.clone();
                let deltas = Matrix::from_vec(
                    64,
                    300,
                    (0..64 * 300).map(|_| rng.next_f32() * 5.0).collect(),
                );
                h.bind("bench-lm", vec![(0, OwnedArg::Mat(lm.clone()))]).unwrap();
                let r = bench("ose_opt exec B=64 T=60 (bound landmarks)", &quick, || {
                    h.execute_bound(
                        &spec.name,
                        "bench-lm",
                        vec![
                            (1, OwnedArg::Mat(deltas.clone())),
                            (2, OwnedArg::Mat(Matrix::zeros(64, 7))),
                            (3, OwnedArg::Scalar(1.0 / 600.0)),
                        ],
                    )
                    .unwrap()
                });
                println!("{}  ({:.0} pts/s)", r.report(), r.throughput(64));
            }
        }
        Err(e) => println!("\n(PJRT benches skipped: {e:#})"),
    }
}
