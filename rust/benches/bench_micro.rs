//! Micro benchmarks of every hot-path component (custom harness — the
//! image vendors no criterion). Prints one line per subject and writes a
//! machine-readable JSON report for the CI perf trajectory.
//!
//!     cargo bench --bench bench_micro
//!
//! Env knobs:
//!   LMDS_BENCH_QUICK=1            short measurement windows (CI smoke)
//!   LMDS_BENCH_JSON=path.json     where to write the report
//!                                 (default BENCH_pr2.json in the CWD)
//!   LMDS_BENCH_JSON_PR7=path.json where to write the kernel-tier report
//!                                 (default BENCH_pr7.json in the CWD)

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use lmds_ose::coordinator::methods::{BackendNn, BackendOpt};
use lmds_ose::coordinator::{BatcherConfig, Request, ServerBuilder};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::{cross_matrix, full_matrix};
use lmds_ose::mds::lsmds::{stress_gradient, stress_gradient_blocked};
use lmds_ose::mds::Matrix;
use lmds_ose::nn::{forward, forward_blocked, MlpParams, MlpShape};
use lmds_ose::ose::pipeline::embed_stream;
use lmds_ose::ose::{embed_point, OseMethod, OseOptConfig};
use lmds_ose::runtime::simd::{
    self, euclidean_sq_scalar, euclidean_sq_vector, set_kernel_tier,
};
use lmds_ose::runtime::{Backend, ComputeBackend, KernelTier, NativeBackend};
use lmds_ose::strdist::{
    jaro_winkler_distance, levenshtein, levenshtein_dp, qgram_distance, Euclidean,
    Levenshtein,
};
use lmds_ose::util::bench::{bench, BenchConfig, BenchResult};
use lmds_ose::util::json::Json;
use lmds_ose::util::prng::Rng;
use lmds_ose::util::threadpool::{default_parallelism, parallel_for_chunks, SyncSlice};

/// Collects results and renders the JSON report.
struct Report {
    results: Vec<BenchResult>,
}

impl Report {
    fn push(&mut self, r: &BenchResult) {
        self.results.push(r.clone());
    }

    fn write(&self, backend_name: &str) {
        self.write_to(backend_name, "LMDS_BENCH_JSON", "BENCH_pr2.json");
    }

    fn write_to(&self, backend_name: &str, env_key: &str, default_path: &str) {
        let path =
            std::env::var(env_key).unwrap_or_else(|_| default_path.to_string());
        let rows: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("median_s", Json::Num(r.median_s)),
                    ("mad_s", Json::Num(r.mad_s)),
                    ("mean_s", Json::Num(r.mean_s)),
                    ("min_s", Json::Num(r.min_s)),
                    ("iters", Json::Num(r.iters as f64)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::Str("bench_micro".into())),
            ("backend", Json::Str(backend_name.into())),
            ("results", Json::Arr(rows)),
        ]);
        match std::fs::write(&path, doc.to_string_pretty()) {
            Ok(()) => println!("\nwrote {} results to {path}", self.results.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

/// Closed-loop serving load (64 in-flight requests) against a fresh
/// string server embedding through `backend`'s MLP forward path; returns
/// the measured p99 latency in seconds.
fn serving_p99(
    landmarks: &[String],
    backend: &Backend,
    params: &MlpParams,
    queries: usize,
) -> f64 {
    let server = ServerBuilder::strings(
        landmarks.to_vec(),
        Arc::new(Levenshtein),
        BackendNn::replica_factory(backend.clone(), params.clone()),
    )
    .batcher(BatcherConfig {
        max_batch: 8,
        max_delay: Duration::from_micros(200),
        queue_cap: 4096,
        frontend_threads: 1,
        replicas: 2,
    })
    .build()
    .expect("valid server configuration");
    let h = server.handle();
    let mut pending = VecDeque::new();
    for i in 0..queries {
        pending.push_back(h.submit(Request::object(format!("query {i}"))));
        if pending.len() >= 64 {
            pending.pop_front().unwrap().recv().expect("reply must arrive");
        }
    }
    while let Some(t) = pending.pop_front() {
        t.recv().expect("reply must arrive");
    }
    let p99 = h.metrics.snapshot().p99_s;
    drop(h);
    server.shutdown();
    p99
}

fn main() {
    lmds_ose::util::logging::init();
    let quick_mode = std::env::var("LMDS_BENCH_QUICK").is_ok();
    let scale = |cfg: BenchConfig| -> BenchConfig {
        if quick_mode {
            BenchConfig {
                warmup: std::time::Duration::from_millis(10),
                measure: std::time::Duration::from_millis(120),
                max_iters: cfg.max_iters.min(500),
                min_iters: 3,
            }
        } else {
            cfg
        }
    };
    let cfg = scale(BenchConfig::default());
    let quick = scale(BenchConfig {
        measure: std::time::Duration::from_millis(500),
        ..BenchConfig::default()
    });
    let heavy = scale(BenchConfig::heavy());
    let mut report = Report { results: Vec::new() };
    let mut rng = Rng::new(1);
    let mut geco = Geco::new(GecoConfig { seed: 2, ..Default::default() });
    let names = geco.generate_unique(2000);

    println!("== string distances ==");
    let mut i = 0usize;
    let r = bench("levenshtein/myers (name pair)", &cfg, || {
        i = (i + 1) % 1999;
        levenshtein(&names[i], &names[i + 1])
    });
    println!("{}  ({:.1}M pairs/s)", r.report(), r.throughput(1) / 1e6);
    report.push(&r);
    let r2 = bench("levenshtein/dp (name pair)", &cfg, || {
        i = (i + 1) % 1999;
        levenshtein_dp(&names[i], &names[i + 1])
    });
    println!("{}  (myers speedup {:.1}x)", r2.report(), r2.median_s / r.median_s);
    report.push(&r2);
    let r = bench("jaro-winkler (name pair)", &quick, || {
        i = (i + 1) % 1999;
        jaro_winkler_distance(&names[i], &names[i + 1])
    });
    println!("{}", r.report());
    report.push(&r);
    let r = bench("qgram2 (name pair)", &quick, || {
        i = (i + 1) % 1999;
        qgram_distance(&names[i], &names[i + 1], 2)
    });
    println!("{}", r.report());
    report.push(&r);

    println!("\n== dissimilarity engine ==");
    let sub: Vec<&str> = names[..500].iter().map(|s| s.as_str()).collect();
    let r = bench("full_matrix 500x500 (parallel)", &heavy, || {
        full_matrix(&sub, &Levenshtein)
    });
    println!("{}  ({:.1}M dists/s)", r.report(), r.throughput(500 * 499 / 2) / 1e6);
    report.push(&r);
    let rows: Vec<&str> = names[500..756].iter().map(|s| s.as_str()).collect();
    let r = bench("cross_matrix 256x500", &heavy, || {
        cross_matrix(&rows, &sub, &Levenshtein)
    });
    println!("{}  ({:.1}M dists/s)", r.report(), r.throughput(256 * 500) / 1e6);
    report.push(&r);

    println!("\n== pure-Rust numeric kernels ==");
    let x = Matrix::random_normal(&mut rng, 300, 7, 1.0);
    let delta = {
        let mut d = Matrix::zeros(300, 300);
        for i in 0..300 {
            for j in 0..300 {
                d.set(i, j, lmds_ose::strdist::euclidean(x.row(i), x.row(j)) as f32);
            }
        }
        d
    };
    let r = bench("stress_gradient N=300 K=7", &quick, || {
        stress_gradient(&x, &delta)
    });
    println!("{}", r.report());
    report.push(&r);
    let lm = Matrix::random_normal(&mut rng, 300, 7, 1.0);
    let dl: Vec<f32> = (0..300).map(|_| rng.next_f32() * 5.0).collect();
    let r = bench("ose embed_point L=300 (serial oracle)", &quick, || {
        embed_point(&lm, &dl, None, &OseOptConfig::default())
    });
    println!("{}", r.report());
    report.push(&r);
    let params = MlpParams::init(
        &MlpShape { input: 300, hidden: [256, 128, 64], output: 7 },
        &mut rng,
    );
    let q = Matrix::from_vec(1, 300, dl.clone());
    let r = bench("mlp forward B=1 L=300 (serial oracle)", &quick, || {
        forward(&params, &q)
    });
    println!("{}", r.report());
    report.push(&r);

    // ---- blocked kernels vs the kernels they replaced (PR 2) ----
    // The acceptance bar: blocked stress_gradient and MLP forward at least
    // 1.5x the old kernels at N >= 2000, recorded in the JSON report.
    println!("\n== blocked kernels vs previous kernels (N=2000) ==");
    {
        let n = 2000usize;
        let k = 7usize;
        let pts: Vec<Vec<f32>> = {
            let mut rng2 = Rng::new(0xb1);
            (0..n)
                .map(|_| (0..k).map(|_| rng2.next_normal() as f32).collect())
                .collect()
        };
        let refs: Vec<&[f32]> = pts.iter().map(|p| p.as_slice()).collect();
        let delta_big = full_matrix(&refs, &Euclidean);
        let x_big = Matrix::from_vec(n, k, pts.iter().flatten().copied().collect());
        // both kernels are row-parallel over the same thread budget, so
        // this isolates the f32/fused-inner-loop + blocking gain
        let r_old = bench("stress_gradient N=2000 K=7 (previous f64 kernel)", &quick, || {
            stress_gradient(&x_big, &delta_big)
        });
        println!("{}", r_old.report());
        report.push(&r_old);
        let r_new = bench("stress_gradient_blocked N=2000 K=7", &quick, || {
            stress_gradient_blocked(&x_big, &delta_big)
        });
        println!(
            "{}  (speedup {:.2}x over previous kernel)",
            r_new.report(),
            r_old.median_s / r_new.median_s
        );
        report.push(&r_new);
    }
    {
        // the old native mlp_fwd walked w.at(i, c) down a column per
        // output; reproduce it here (parallel over rows, like the old
        // backend) so the JSON keeps an honest old-vs-new comparison
        fn forward_row_strided(params: &MlpParams, row: &[f32]) -> Vec<f32> {
            let mut cur = row.to_vec();
            for l in 0..4 {
                let w = &params.w[l];
                let b = &params.b[l];
                let mut next = vec![0.0f32; w.cols];
                for (c, out) in next.iter_mut().enumerate() {
                    let mut acc = b[c];
                    for (i, xv) in cur.iter().enumerate() {
                        acc += xv * w.at(i, c);
                    }
                    *out = acc;
                }
                if l < 3 {
                    for v in next.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                cur = next;
            }
            cur
        }
        let b = 256usize;
        let input = Matrix::from_vec(
            b,
            300,
            (0..b * 300).map(|_| rng.next_f32() * 5.0).collect(),
        );
        let r_old = bench("mlp fwd B=256 L=300 (strided row kernel)", &quick, || {
            let k = params.shape.output;
            let mut out = Matrix::zeros(b, k);
            let slots = SyncSlice::new(&mut out.data);
            parallel_for_chunks(b, 8, default_parallelism(), |start, end| {
                for r in start..end {
                    let y = forward_row_strided(&params, input.row(r));
                    // SAFETY: row r is owned by this chunk; each output
                    // cell is written exactly once.
                    unsafe {
                        for (c, v) in y.iter().enumerate() {
                            slots.write(r * k + c, *v);
                        }
                    }
                }
            });
            out
        });
        println!("{}", r_old.report());
        report.push(&r_old);
        let r_new = bench("mlp fwd B=256 L=300 (blocked kernel)", &quick, || {
            NativeBackend.mlp_fwd(&params, &input).unwrap()
        });
        println!(
            "{}  (speedup {:.2}x over strided kernel)",
            r_new.report(),
            r_old.median_s / r_new.median_s
        );
        report.push(&r_new);
    }

    // ---- streaming pipeline: monolithic vs overlapped chunks ----
    println!("\n== streaming embed pipeline (N=4096, L=300) ==");
    {
        let n = 4096usize;
        let stream_names = geco.generate_unique(n + 300);
        let q_refs: Vec<&str> = stream_names[..n].iter().map(|s| s.as_str()).collect();
        let lm_refs: Vec<&str> =
            stream_names[n..].iter().map(|s| s.as_str()).collect();
        let lm_cfg = Matrix::random_normal(&mut rng, 300, 7, 1.0);
        let mk = || {
            let mut m = BackendOpt::with_defaults(Backend::native(), lm_cfg.clone());
            m.total_steps = 30;
            m.rel_tol = 0.0;
            m
        };
        let r_mono = bench("embed monolithic (cross_matrix + embed)", &quick, || {
            let delta = cross_matrix(&q_refs, &lm_refs, &Levenshtein);
            mk().embed(&delta).unwrap()
        });
        println!("{}  ({:.0} pts/s)", r_mono.report(), r_mono.throughput(n));
        report.push(&r_mono);
        let r_stream = bench("embed streaming chunk=512 (overlapped)", &quick, || {
            let mut m = mk();
            embed_stream(&q_refs, &lm_refs, &Levenshtein, &mut m, 512).unwrap()
        });
        println!(
            "{}  ({:.0} pts/s, {:.2}x vs monolithic)",
            r_stream.report(),
            r_stream.throughput(n),
            r_mono.median_s / r_stream.median_s
        );
        report.push(&r_stream);
    }

    // Compute-backend execution (native always; PJRT when built with
    // --features pjrt and artifacts + bindings are available).
    let backend = Backend::auto();
    println!("\n== compute backend: {} (L=300) ==", backend.name());
    for b in [1usize, 64, 256] {
        let mut method = BackendNn::new(backend.clone(), params.clone());
        let input = Matrix::from_vec(
            b,
            300,
            (0..b * 300).map(|_| rng.next_f32() * 5.0).collect(),
        );
        let r = bench(
            &format!("mlp_fwd exec B={b} ({})", backend.name()),
            &quick,
            || method.embed(&input).unwrap(),
        );
        println!("{}  ({:.0} pts/s)", r.report(), r.throughput(b));
        report.push(&r);
    }
    {
        let mut method = BackendOpt::with_defaults(backend.clone(), lm.clone());
        method.total_steps = 60;
        method.rel_tol = 0.0; // fixed work per iteration: comparable across PRs
        let deltas = Matrix::from_vec(
            64,
            300,
            (0..64 * 300).map(|_| rng.next_f32() * 5.0).collect(),
        );
        let r = bench(
            &format!("ose_opt exec B=64 T=60 ({})", backend.name()),
            &quick,
            || method.embed(&deltas).unwrap(),
        );
        println!("{}  ({:.0} pts/s)", r.report(), r.throughput(64));
        report.push(&r);
    }

    report.write(backend.name());

    // ---- kernel tier: simd vs scalar vs serial (PR 7) ----
    // The acceptance bar: the vector tier beats the scalar tier on all
    // three vectorised kernels, and the end-to-end A/B rows (stage 1 base
    // solve, stage 2 streamed embedding, serving p99) record the carry-
    // through. Written to a second report (BENCH_pr7.json) so the per-PR
    // perf trajectories stay separable.
    let mut report7 = Report { results: Vec::new() };
    println!(
        "\n== kernel tier A/B (auto resolves to: {}, vector ISA: {}) ==",
        simd::active_tier_name(),
        simd::simd_supported()
    );
    {
        // (a) storage-layer metric kernel (strdist::metric euclidean_sq)
        let va: Vec<f32> = (0..300).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let vb: Vec<f32> = (0..300).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        // the historical pre-tier kernel: a serial left-fold
        fn serial_sq(a: &[f32], b: &[f32]) -> f64 {
            let mut acc = 0.0f64;
            for (x, y) in a.iter().zip(b.iter()) {
                let d = (x - y) as f64;
                acc += d * d;
            }
            acc
        }
        let r_ser = bench("euclidean_sq d=300 (serial left-fold)", &cfg, || {
            serial_sq(&va, &vb)
        });
        println!("{}", r_ser.report());
        report7.push(&r_ser);
        let r_sc = bench("euclidean_sq d=300 (scalar tier)", &cfg, || {
            euclidean_sq_scalar(&va, &vb)
        });
        println!("{}", r_sc.report());
        report7.push(&r_sc);
        let r_vec = bench("euclidean_sq d=300 (simd tier)", &cfg, || {
            euclidean_sq_vector(&va, &vb)
        });
        println!(
            "{}  (simd {:.2}x over scalar, {:.2}x over serial)",
            r_vec.report(),
            r_sc.median_s / r_vec.median_s,
            r_ser.median_s / r_vec.median_s
        );
        report7.push(&r_vec);
    }
    let (x7, delta7) = {
        // shared N=1200 K=7 problem for the stress and stage-1 rows
        let n = 1200usize;
        let k = 7usize;
        let pts: Vec<Vec<f32>> = {
            let mut rng2 = Rng::new(0xc7);
            (0..n)
                .map(|_| (0..k).map(|_| rng2.next_normal() as f32).collect())
                .collect()
        };
        let refs: Vec<&[f32]> = pts.iter().map(|p| p.as_slice()).collect();
        let delta = full_matrix(&refs, &Euclidean);
        let x = Matrix::from_vec(n, k, pts.iter().flatten().copied().collect());
        (x, delta)
    };
    {
        // (b) LSMDS stress/gradient kernel (mds::lsmds stress_row_tile)
        let r_ser = bench("stress_gradient N=1200 K=7 (serial oracle)", &quick, || {
            stress_gradient(&x7, &delta7)
        });
        println!("{}", r_ser.report());
        report7.push(&r_ser);
        set_kernel_tier(KernelTier::Scalar);
        let r_sc =
            bench("stress_gradient_blocked N=1200 K=7 (scalar tier)", &quick, || {
                stress_gradient_blocked(&x7, &delta7)
            });
        println!("{}", r_sc.report());
        report7.push(&r_sc);
        set_kernel_tier(KernelTier::Simd);
        let r_vec =
            bench("stress_gradient_blocked N=1200 K=7 (simd tier)", &quick, || {
                stress_gradient_blocked(&x7, &delta7)
            });
        println!(
            "{}  (simd {:.2}x over scalar, {:.2}x over serial)",
            r_vec.report(),
            r_sc.median_s / r_vec.median_s,
            r_ser.median_s / r_vec.median_s
        );
        report7.push(&r_vec);
    }
    {
        // (c) MLP forward microkernel (nn::forward_block affine_into)
        let b = 256usize;
        let input = Matrix::from_vec(
            b,
            300,
            (0..b * 300).map(|_| rng.next_f32() * 5.0).collect(),
        );
        let r_ser = bench("mlp forward B=256 L=300 (serial oracle)", &quick, || {
            forward(&params, &input)
        });
        println!("{}", r_ser.report());
        report7.push(&r_ser);
        set_kernel_tier(KernelTier::Scalar);
        let r_sc = bench("forward_blocked B=256 L=300 (scalar tier)", &quick, || {
            forward_blocked(&params, &input)
        });
        println!("{}", r_sc.report());
        report7.push(&r_sc);
        set_kernel_tier(KernelTier::Simd);
        let r_vec = bench("forward_blocked B=256 L=300 (simd tier)", &quick, || {
            forward_blocked(&params, &input)
        });
        println!(
            "{}  (simd {:.2}x over scalar, {:.2}x over serial)",
            r_vec.report(),
            r_sc.median_s / r_vec.median_s,
            r_ser.median_s / r_vec.median_s
        );
        report7.push(&r_vec);
    }
    {
        // (d) stage 1 A/B: the base solve carry-through
        let native = NativeBackend;
        set_kernel_tier(KernelTier::Scalar);
        let r_sc = bench("lsmds_steps N=1200 T=5 (scalar tier)", &quick, || {
            native.lsmds_steps(&x7, &delta7, 1.0 / 2400.0, 5).unwrap()
        });
        println!("{}", r_sc.report());
        report7.push(&r_sc);
        set_kernel_tier(KernelTier::Simd);
        let r_vec = bench("lsmds_steps N=1200 T=5 (simd tier)", &quick, || {
            native.lsmds_steps(&x7, &delta7, 1.0 / 2400.0, 5).unwrap()
        });
        println!(
            "{}  (simd {:.2}x over scalar)",
            r_vec.report(),
            r_sc.median_s / r_vec.median_s
        );
        report7.push(&r_vec);
    }
    {
        // (e) stage 2 A/B: vector-metric cross_matrix + streamed-equivalent
        // batch embedding over the solved landmarks
        let lm_cfg = Matrix::random_normal(&mut rng, 300, 7, 1.0);
        let q_pts: Vec<Vec<f32>> = {
            let mut rng2 = Rng::new(0xd2);
            (0..1024)
                .map(|_| (0..7).map(|_| rng2.next_normal() as f32).collect())
                .collect()
        };
        let lm_pts: Vec<Vec<f32>> = {
            let mut rng2 = Rng::new(0xd3);
            (0..300)
                .map(|_| (0..7).map(|_| rng2.next_normal() as f32).collect())
                .collect()
        };
        let q_refs: Vec<&[f32]> = q_pts.iter().map(|p| p.as_slice()).collect();
        let lm_refs: Vec<&[f32]> = lm_pts.iter().map(|p| p.as_slice()).collect();
        let run = |label: &str| {
            bench(label, &quick, || {
                let delta = cross_matrix(&q_refs, &lm_refs, &Euclidean);
                let mut m =
                    BackendOpt::with_defaults(Backend::native(), lm_cfg.clone());
                m.total_steps = 20;
                m.rel_tol = 0.0;
                m.embed(&delta).unwrap()
            })
        };
        set_kernel_tier(KernelTier::Scalar);
        let r_sc = run("stage2 embed B=1024 L=300 (scalar tier)");
        println!("{}", r_sc.report());
        report7.push(&r_sc);
        set_kernel_tier(KernelTier::Simd);
        let r_vec = run("stage2 embed B=1024 L=300 (simd tier)");
        println!(
            "{}  (simd {:.2}x over scalar)",
            r_vec.report(),
            r_sc.median_s / r_vec.median_s
        );
        report7.push(&r_vec);
    }
    {
        // (f) serving p99 A/B: one closed-loop run per tier, recorded as a
        // single-sample row (median == the measured p99 seconds)
        let lm_names: Vec<String> = names[..300].to_vec();
        let queries = if quick_mode { 400 } else { 3000 };
        let backend = Backend::native();
        let mut p99_row = |label: &str, p99: f64| {
            let r = BenchResult {
                name: label.to_string(),
                iters: queries,
                samples_s: vec![p99],
                median_s: p99,
                mad_s: 0.0,
                mean_s: p99,
                min_s: p99,
            };
            println!("{label}: p99 {:.3} ms", p99 * 1e3);
            report7.push(&r);
            r
        };
        set_kernel_tier(KernelTier::Scalar);
        let r_sc = p99_row(
            "serving p99 seconds (scalar tier)",
            serving_p99(&lm_names, &backend, &params, queries),
        );
        set_kernel_tier(KernelTier::Simd);
        let r_vec = p99_row(
            "serving p99 seconds (simd tier)",
            serving_p99(&lm_names, &backend, &params, queries),
        );
        println!(
            "  (simd p99 {:.2}x over scalar)",
            r_sc.median_s / r_vec.median_s
        );
    }
    set_kernel_tier(KernelTier::Auto);
    report7.write_to(backend.name(), "LMDS_BENCH_JSON_PR7", "BENCH_pr7.json");
}
