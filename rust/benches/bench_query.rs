//! Query-path bench: dense all-landmark OSE vs the sparse `query_k` path
//! through the landmark small-world graph (docs/QUERY_PATH.md), plus
//! graph-assisted landmark selection vs the exact FPS scan. Writes a
//! machine-readable JSON report for the CI perf trajectory.
//!
//!     cargo bench --bench bench_query
//!
//! Env knobs:
//!   LMDS_BENCH_QUICK=1        fewer queries / steps (CI smoke)
//!   LMDS_BENCH_JSON=path.json where to write the report
//!                             (default BENCH_pr9.json in the CWD)
//!
//! Per-query latency is measured on the method itself (one delta row per
//! `embed` call, no serving queue in the way), with a fixed majorization
//! budget so dense and sparse run the same number of steps — the
//! difference is purely O(L·steps) vs O(k log L + k·steps) work. The
//! sampled residual stress of both paths is reported next to the
//! latencies so a speedup can never silently buy a quality regression.

use std::sync::Arc;
use std::time::Instant;

use lmds_ose::coordinator::methods::BackendOpt;
use lmds_ose::mds::divide::{fps_anchors, PointsDelta};
use lmds_ose::mds::graph::{graph_landmarks, GraphConfig, LandmarkGraph};
use lmds_ose::mds::Matrix;
use lmds_ose::ose::OseMethod;
use lmds_ose::runtime::Backend;
use lmds_ose::util::json::Json;
use lmds_ose::util::prng::Rng;

const K: usize = 8;
const QUERY_K: usize = 32;

fn delta_to(config: &Matrix, q: &[f32]) -> Vec<f32> {
    (0..config.rows)
        .map(|i| {
            config
                .row(i)
                .iter()
                .zip(q)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt()
        })
        .collect()
}

fn opt_method(
    config: &Matrix,
    steps: usize,
    query_k: usize,
    graph: Option<Arc<LandmarkGraph>>,
) -> BackendOpt {
    BackendOpt {
        backend: Backend::native(),
        landmarks: config.clone(),
        total_steps: steps,
        lr: None,
        rel_tol: 0.0,
        query_k,
        graph,
    }
}

/// Per-query latencies (seconds, one embed call per row), plus the
/// sampled residual stress of the produced embeddings: for each query,
/// `sample` landmark distances are re-predicted from the embedding and
/// compared against the true delta row.
fn run_queries(
    method: &mut BackendOpt,
    config: &Matrix,
    deltas: &[Vec<f32>],
    sample: usize,
) -> (Vec<f64>, f64) {
    let l = config.rows;
    let mut lat = Vec::with_capacity(deltas.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    let mut rng = Rng::new(0x57e5);
    for delta in deltas {
        let row = Matrix::from_vec(1, l, delta.clone());
        let t0 = Instant::now();
        let y = method.embed(&row).expect("bench embed");
        lat.push(t0.elapsed().as_secs_f64());
        for _ in 0..sample {
            let j = rng.index(l);
            let d_hat = config
                .row(j)
                .iter()
                .zip(y.row(0))
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            num += (d_hat - delta[j] as f64).powi(2);
            den += (delta[j] as f64).powi(2);
        }
    }
    (lat, (num / den).sqrt())
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn main() {
    lmds_ose::util::logging::init();
    let quick = std::env::var("LMDS_BENCH_QUICK").is_ok();
    let steps = if quick { 24 } else { 60 };
    let queries = if quick { 24 } else { 120 };
    let stress_sample = 2000usize;

    let mut scales: Vec<Json> = Vec::new();
    println!(
        "== query path: dense vs query_k={QUERY_K} (dim {K}, {steps} steps, \
         {queries} queries per scale) =="
    );
    for l in [10_000usize, 100_000] {
        let mut rng = Rng::new(0x9a27 ^ l as u64);
        let config = Matrix::random_normal(&mut rng, l, K, 1.0);
        let t0 = Instant::now();
        let graph = Arc::new(LandmarkGraph::build(&config, &GraphConfig::default()));
        let build_s = t0.elapsed().as_secs_f64();

        let deltas: Vec<Vec<f32>> = (0..queries)
            .map(|_| {
                let q: Vec<f32> = (0..K).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
                delta_to(&config, &q)
            })
            .collect();

        let mut dense = opt_method(&config, steps, 0, None);
        let (mut lat_d, stress_d) =
            run_queries(&mut dense, &config, &deltas, stress_sample);
        lat_d.sort_by(f64::total_cmp);

        let mut sparse =
            opt_method(&config, steps, QUERY_K, Some(Arc::clone(&graph)));
        let (mut lat_s, stress_s) =
            run_queries(&mut sparse, &config, &deltas, stress_sample);
        lat_s.sort_by(f64::total_cmp);

        let speedup = pct(&lat_d, 0.5) / pct(&lat_s, 0.5).max(1e-12);
        println!(
            "L={l:6}: dense p50 {:8.3}ms p99 {:8.3}ms | sparse p50 {:8.3}ms \
             p99 {:8.3}ms | p50 speedup {speedup:6.1}x | stress {stress_d:.4} \
             -> {stress_s:.4} | graph build {build_s:.2}s",
            pct(&lat_d, 0.5) * 1e3,
            pct(&lat_d, 0.99) * 1e3,
            pct(&lat_s, 0.5) * 1e3,
            pct(&lat_s, 0.99) * 1e3,
        );
        scales.push(Json::obj(vec![
            ("l", Json::Num(l as f64)),
            ("query_k", Json::Num(QUERY_K as f64)),
            ("dense_p50_s", Json::Num(pct(&lat_d, 0.5))),
            ("dense_p99_s", Json::Num(pct(&lat_d, 0.99))),
            ("sparse_p50_s", Json::Num(pct(&lat_s, 0.5))),
            ("sparse_p99_s", Json::Num(pct(&lat_s, 0.99))),
            ("speedup_p50", Json::Num(speedup)),
            ("stress_dense", Json::Num(stress_d)),
            ("stress_sparse", Json::Num(stress_s)),
            ("graph_build_s", Json::Num(build_s)),
        ]));
    }

    // landmark selection: exact FPS scan vs graph-assisted maxmin
    let n = if quick { 20_000 } else { 100_000 };
    let l_sel = 128usize;
    let mut rng = Rng::new(0x5e1ec7);
    let points = Matrix::random_normal(&mut rng, n, K, 1.0);
    let source = PointsDelta { points: &points };
    let t0 = Instant::now();
    let picked_fps = fps_anchors(&source, l_sel, 7);
    let fps_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let picked_graph = graph_landmarks(&source, l_sel, &GraphConfig::default(), 7);
    let graph_s = t0.elapsed().as_secs_f64();
    assert_eq!(picked_fps.len(), l_sel);
    assert_eq!(picked_graph.len(), l_sel);
    let sel_speedup = fps_s / graph_s.max(1e-12);
    println!(
        "selection N={n} l={l_sel}: fps {fps_s:.3}s | graph {graph_s:.3}s \
         | {sel_speedup:.1}x"
    );

    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_query".into())),
        ("backend", Json::Str("native".into())),
        ("method", Json::Str("opt".into())),
        ("dim", Json::Num(K as f64)),
        ("steps", Json::Num(steps as f64)),
        ("queries", Json::Num(queries as f64)),
        ("scales", Json::Arr(scales)),
        (
            "selection",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("l", Json::Num(l_sel as f64)),
                ("fps_s", Json::Num(fps_s)),
                ("graph_s", Json::Num(graph_s)),
                ("speedup", Json::Num(sel_speedup)),
            ]),
        ),
    ]);
    let path = std::env::var("LMDS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_pr9.json".to_string());
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote query bench report to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
