//! Refresh-loop bench: cost of closing the streaming loop. Embeds a Geco
//! corpus, serves it with the drift monitor armed, pushes an
//! out-of-distribution storm through the handle and measures the hot
//! refresh end to end — time to the drift signal, the shadow
//! solve + swap wall time, and the drain of the retired generation —
//! plus serving latency before and after the swap.
//!
//!     cargo bench --bench bench_refresh
//!
//! Env knobs:
//!   LMDS_BENCH_QUICK=1        smaller corpus + query volume (CI smoke)
//!   LMDS_BENCH_JSON=path.json where to write the report
//!                             (default BENCH_pr10.json in the CWD)

use std::sync::Arc;
use std::time::{Duration, Instant};

use lmds_ose::coordinator::{
    embed_corpus, BaseSolver, BatcherConfig, DriftConfig, DriftHook, OseBackend,
    PipelineConfig, RefreshConfig, RefreshController, Request, ServerBuilder,
    ServerHandle,
};
use lmds_ose::data::source::{
    CorpusWriter, ObjectTable, TableDelta, DEFAULT_CACHE_BUDGET,
};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::{LandmarkMethod, LsmdsConfig};
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Levenshtein;
use lmds_ose::util::json::Json;

const SEED: u64 = 40246;

fn run_queries(h: &ServerHandle<str>, queries: impl IntoIterator<Item = String>) -> usize {
    let tickets: Vec<_> = queries
        .into_iter()
        .map(|q| h.submit(Request::object(q)))
        .collect();
    let n = tickets.len();
    for t in tickets {
        t.recv().expect("bench load must not fail");
    }
    n
}

fn main() {
    lmds_ose::util::logging::init();
    let quick = std::env::var("LMDS_BENCH_QUICK").is_ok();
    let n = if quick { 1_500 } else { 10_000 };
    let landmarks = if quick { 64 } else { 200 };
    let in_dist = if quick { 200 } else { 1_000 };

    // corpus on disk: the refresh appends ingested queries to it
    let mut geco = Geco::new(GecoConfig { seed: SEED, ..Default::default() });
    let names = geco.generate_unique(n);
    let path = std::env::temp_dir()
        .join(format!("lmds_bench_refresh_{}", std::process::id()));
    let mut w = CorpusWriter::create_text(&path).unwrap();
    for name in &names {
        w.push_text(name).unwrap();
    }
    w.finish().unwrap();

    let pcfg = PipelineConfig {
        dim: 3,
        landmarks,
        landmark_method: LandmarkMethod::Random,
        backend: OseBackend::Opt,
        base_solver: BaseSolver::DivideConquer { blocks: 4, anchors: 0 },
        lsmds: LsmdsConfig { dim: 3, max_iters: 200, ..Default::default() },
        ose_steps: Some(6),
        seed: SEED,
        ..Default::default()
    };
    let backend = Backend::native();

    println!("== refresh loop: N={n}, L={landmarks}, opt OSE, divide base ==");
    let t0 = Instant::now();
    let (r, landmark_objs) = {
        let table = ObjectTable::open(&path, DEFAULT_CACHE_BUDGET).unwrap();
        let source = TableDelta::text(&table, &Levenshtein).unwrap();
        let r = embed_corpus(&source, &pcfg, &backend).unwrap();
        let objs = table.text_rows(&r.landmark_idx);
        (r, objs)
    };
    let embed_s = t0.elapsed().as_secs_f64();
    println!("initial embed: {embed_s:.2}s (landmark stress {:.4})", r.landmark_stress);

    let server = ServerBuilder::strings(
        landmark_objs,
        Arc::new(Levenshtein),
        Arc::clone(&r.factory),
    )
    .batcher(BatcherConfig {
        max_delay: Duration::from_micros(200),
        replicas: 2,
        ..Default::default()
    })
    .landmark_config(r.landmark_config.clone())
    .backend(backend.clone())
    .drift(DriftHook {
        landmark_config: r.landmark_config.clone(),
        cfg: DriftConfig { window: 64, calibration: 64, degrade_factor: 1.3 },
    })
    .build()
    .expect("valid server configuration");
    let h = server.handle();
    let ctl = RefreshController::start(
        h.clone(),
        path.clone(),
        pcfg,
        backend,
        r.landmark_idx.clone(),
        r.landmark_config.clone(),
        // manual refresh: the bench times run_once itself
        RefreshConfig { poll: Duration::from_secs(3600), ..Default::default() },
    )
    .expect("starting the refresh controller");

    // phase 1 — in-distribution traffic: calibrates the monitor, fills
    // the ingest buffer, gives a pre-drift latency baseline
    let mut geco = Geco::new(GecoConfig { seed: SEED ^ 0xA, ..Default::default() });
    let t0 = Instant::now();
    run_queries(&h, (0..in_dist).map(|q| geco.corrupt(&names[(q * 31) % n])));
    let baseline_wall = t0.elapsed().as_secs_f64();
    let pre = h.metrics.snapshot();
    println!(
        "in-distribution: {in_dist} queries in {baseline_wall:.2}s \
         (p50 {:.3}ms, drift signals {})",
        pre.p50_s * 1e3,
        pre.drift_signals
    );

    // phase 2 — OOD storm until the monitor signals
    let t0 = Instant::now();
    let mut storm = 0usize;
    while h.metrics.snapshot().drift_signals == 0 {
        storm += run_queries(
            &h,
            (0..32).map(|k| format!("qqqqqqqqqqqqqqqqqqqqqqqqqqqq{:04}", storm + k)),
        );
        assert!(storm < 1_000_000, "drift monitor never signalled");
    }
    let signal_wall = t0.elapsed().as_secs_f64();
    println!("OOD storm: drift signalled after {storm} queries ({signal_wall:.2}s)");

    // phase 3 — the refresh itself: ingest + shadow solve + align + swap
    let t0 = Instant::now();
    let report = ctl.run_once().expect("refresh must complete");
    let refresh_wall = t0.elapsed().as_secs_f64();
    println!(
        "refresh: {refresh_wall:.2}s wall | ingested {} | landmark stress {:.4} \
         | align rmsd {:.4} | swap drain {:?}",
        report.ingested, report.landmark_stress, report.align_rmsd, report.swap_drain
    );

    // phase 4 — post-swap traffic on the new generation
    let t0 = Instant::now();
    run_queries(
        &h,
        (0..in_dist).map(|k| format!("qqqqqqqqqqqqqqqqqqqqqqqqqqqq{:04}", 500_000 + k)),
    );
    let post_wall = t0.elapsed().as_secs_f64();
    let snap = h.metrics.snapshot();
    assert_eq!(snap.failed, 0, "bench load must not fail");
    assert_eq!(snap.generation, 1);
    println!(
        "post-refresh: {in_dist} queries in {post_wall:.2}s \
         (cumulative p50 {:.3}ms, footprint {} slots)",
        snap.p50_s * 1e3,
        snap.metrics_footprint
    );

    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_refresh".into())),
        ("backend", Json::Str("native".into())),
        ("method", Json::Str("opt".into())),
        ("n", Json::Num(n as f64)),
        ("landmarks", Json::Num(landmarks as f64)),
        ("initial_embed_s", Json::Num(embed_s)),
        ("initial_stress", Json::Num(r.landmark_stress)),
        ("baseline_qps", Json::Num(in_dist as f64 / baseline_wall)),
        ("storm_queries_to_signal", Json::Num(storm as f64)),
        ("refresh_wall_s", Json::Num(refresh_wall)),
        ("refresh_ingested", Json::Num(report.ingested as f64)),
        ("refresh_stress", Json::Num(report.landmark_stress)),
        // NaN means the alignment was skipped (thin landmark overlap);
        // encode it as -1 so the report stays valid JSON
        (
            "align_rmsd",
            Json::Num(if report.align_rmsd.is_finite() { report.align_rmsd } else { -1.0 }),
        ),
        ("swap_drain_ms", Json::Num(report.swap_drain.as_millis() as f64)),
        ("post_refresh_qps", Json::Num(in_dist as f64 / post_wall)),
        ("p50_s", Json::Num(snap.p50_s)),
        ("p99_s", Json::Num(snap.p99_s)),
        ("metrics_footprint", Json::Num(snap.metrics_footprint as f64)),
    ]);
    let path_json = std::env::var("LMDS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_pr10.json".to_string());
    match std::fs::write(&path_json, doc.to_string_pretty()) {
        Ok(()) => println!("wrote refresh bench report to {path_json}"),
        Err(e) => eprintln!("could not write {path_json}: {e}"),
    }

    ctl.stop();
    drop(h);
    server.shutdown();
    std::fs::remove_file(&path).ok();
}
