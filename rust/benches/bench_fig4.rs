//! Figure 4 reproduction bench: mean RT of mapping one out-of-sample point
//! vs L, for both OSE methods, plus the Sec.-5.3.3 headline numbers.
//!
//!     cargo bench --bench bench_fig4
//!
//! Scale via LMDS_BENCH_SCALE (default small). Writes
//! results/fig4_<scale>.json.

use lmds_ose::eval::figures;
use lmds_ose::eval::protocol::{load_or_build, Scale};
use lmds_ose::runtime::{Backend, ComputeBackend};

fn main() {
    lmds_ose::util::logging::init();
    let scale = std::env::var("LMDS_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::from_name(&s))
        .unwrap_or(Scale::Small);
    let epochs: usize = std::env::var("LMDS_BENCH_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12); // inference RT does not depend on training quality

    let backend = Backend::auto();
    eprintln!("compute backend: {}", backend.name());
    let data = load_or_build(scale, 7, &backend).expect("protocol data");

    let rows = figures::fig4(&data, &backend, epochs).expect("fig4");
    figures::headline(&data, &backend, epochs).expect("headline");

    // paper shape: RT grows with L for the optimisation method; the NN is
    // faster at every L
    let slower = rows.iter().filter(|r| r.rt_opt > r.rt_nn).count();
    eprintln!(
        "\nshape checks: nn faster at {slower}/{} sweep points; \
         opt RT grows {:.1}x across the sweep",
        rows.len(),
        rows.last().unwrap().rt_opt / rows.first().unwrap().rt_opt
    );
}
