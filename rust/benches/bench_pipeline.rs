//! End-to-end pipeline + serving benches: the wall-clock story a systems
//! reader wants — how long each phase of the two-stage pipeline takes and
//! what the serving layer sustains.
//!
//!     cargo bench --bench bench_pipeline

use std::sync::Arc;
use std::time::{Duration, Instant};

use lmds_ose::coordinator::embedder::{embed_dataset, OseBackend, PipelineConfig};
use lmds_ose::coordinator::trainer::TrainConfig;
use lmds_ose::coordinator::{BatcherConfig, Request, ServerBuilder};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::LsmdsConfig;
use lmds_ose::ose::OseMethod;
use lmds_ose::runtime::{Backend, ComputeBackend};
use lmds_ose::strdist::Levenshtein;

fn main() {
    lmds_ose::util::logging::init();
    let n = 3000;
    let mut geco = Geco::new(GecoConfig { seed: 0xbe9c, ..Default::default() });
    let names = geco.generate_unique(n);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let backend = Backend::auto();

    println!("== two-stage pipeline (N={n}, L=300, K=7, backend={}) ==", backend.name());
    for ose in [OseBackend::Opt, OseBackend::Nn] {
        let cfg = PipelineConfig {
            dim: 7,
            landmarks: 300,
            backend: ose,
            lsmds: LsmdsConfig { dim: 7, max_iters: 250, ..Default::default() },
            train: TrainConfig { epochs: 60, lr: 3e-3, ..Default::default() },
            ..Default::default()
        };
        let t0 = Instant::now();
        let r = embed_dataset(&objs, &Levenshtein, &cfg, &backend).unwrap();
        let total = t0.elapsed().as_secs_f64();
        let t = &r.timings;
        println!(
            "{:?} via {:<9} total {total:6.2}s | select {:.2}s dLL {:.2}s \
             lsmds {:.2}s train {:.2}s dML {:.2}s ose {:.2}s | stress {:.4}",
            ose, r.method.name(), t.select_s, t.delta_ll_s, t.lsmds_s,
            t.train_s, t.delta_ml_s, t.ose_s, r.landmark_stress
        );
    }

    println!("\n== serving throughput (NN backend, 8 clients) ==");
    let cfg = PipelineConfig {
        dim: 7,
        landmarks: 300,
        backend: OseBackend::Nn,
        lsmds: LsmdsConfig { dim: 7, max_iters: 200, ..Default::default() },
        train: TrainConfig { epochs: 60, lr: 3e-3, ..Default::default() },
        ..Default::default()
    };
    let result = embed_dataset(&objs, &Levenshtein, &cfg, &backend).unwrap();
    let landmark_names: Vec<String> =
        result.landmark_idx.iter().map(|&i| names[i].clone()).collect();
    let server = ServerBuilder::strings(
        landmark_names,
        Arc::new(Levenshtein),
        result.factory.clone(),
    )
    .batcher(BatcherConfig {
        max_batch: 64,
        max_delay: Duration::from_millis(2),
        queue_cap: 8192,
        frontend_threads: 8,
        replicas: 4,
    })
    .build()
    .expect("valid server configuration");
    let h = server.handle();
    for _ in 0..64 {
        let _ = h.submit(Request::object("warm up")).recv();
    }
    let queries = 10_000usize;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..8usize {
            let h = h.clone();
            let names = &names;
            scope.spawn(move || {
                let mut geco =
                    Geco::new(GecoConfig { seed: 91 + c as u64, ..Default::default() });
                let mut pending = Vec::with_capacity(64);
                for q in 0..queries / 8 {
                    let base = &names[(q * 37 + c * 101) % names.len()];
                    pending.push(h.submit(Request::object(geco.corrupt(base))));
                    if pending.len() >= 64 {
                        for t in pending.drain(..) {
                            t.recv().unwrap();
                        }
                    }
                }
                for t in pending {
                    t.recv().unwrap();
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let snap = h.metrics.snapshot();
    println!(
        "{} queries in {wall:.2}s -> {:.0} q/s | p50 {:.2}ms p99 {:.2}ms | \
         mean batch {:.1}, exec {:.2}ms",
        snap.completed,
        snap.completed as f64 / wall,
        snap.p50_s * 1e3,
        snap.p99_s * 1e3,
        snap.mean_batch_size,
        snap.mean_batch_exec_s * 1e3
    );
    drop(h);
    server.shutdown();
}
