//! Figure 1 reproduction bench: Err(m) vs L for both OSE methods.
//!
//!     cargo bench --bench bench_fig1
//!
//! Scale via env: LMDS_BENCH_SCALE=smoke|small|paper (default small) and
//! LMDS_BENCH_EPOCHS (default 60). Writes results/fig1_<scale>.json.

use lmds_ose::eval::figures;
use lmds_ose::eval::protocol::{load_or_build, Scale};
use lmds_ose::runtime::{Backend, ComputeBackend};

fn main() {
    lmds_ose::util::logging::init();
    let scale = std::env::var("LMDS_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::from_name(&s))
        .unwrap_or(Scale::Small);
    let epochs: usize = std::env::var("LMDS_BENCH_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);

    let backend = Backend::auto();
    eprintln!("compute backend: {}", backend.name());
    let t0 = std::time::Instant::now();
    let data = load_or_build(scale, 7, &backend).expect("protocol data");
    eprintln!("protocol data ready in {:.1}s", t0.elapsed().as_secs_f64());

    let rows = figures::fig1(&data, &backend, epochs).expect("fig1");

    // shape assertions mirroring the paper's qualitative claims
    let first = &rows[0];
    let last = &rows[rows.len() - 1];
    eprintln!(
        "\nshape checks: opt error falls {:.1}x from L={} to L={}; \
         nn varies {:.1}x over the sweep",
        first.err_opt / last.err_opt,
        first.l,
        last.l,
        rows.iter().map(|r| r.err_nn).fold(0.0, f64::max)
            / rows.iter().map(|r| r.err_nn).fold(f64::INFINITY, f64::min),
    );
}
