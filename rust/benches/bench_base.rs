//! Base-solver benchmark: monolithic LSMDS vs the divide-and-conquer
//! solver (partitioned parallel blocks + Procrustes stitching) at
//! L in {2k, 10k, 50k}, with solution quality (sampled normalised stress)
//! reported next to wall-clock so speed never hides a broken stitch.
//!
//!     cargo bench --bench bench_base
//!
//! Env knobs:
//!   LMDS_BENCH_QUICK=1        CI smoke: L in {2k, 10k}, fewer iterations,
//!                             one sample per subject
//!   LMDS_BENCH_JSON=path.json report path (default BENCH_pr4.json)
//!
//! The 50k point (full mode only) runs the divide solver alone from a
//! matrix-free `PointsDelta` source: the monolithic path would need the
//! 10 GB L x L matrix that the divide design exists to avoid, so it is
//! reported as skipped rather than silently downscaled.

use lmds_ose::coordinator::embedder::lsmds_landmarks_config;
use lmds_ose::mds::divide::{
    auto_anchors, block_seed, divide_solve_with, sampled_normalized_stress,
    DeltaSource, DivideConfig, PointsDelta,
};
use lmds_ose::mds::dissimilarity::full_matrix;
use lmds_ose::mds::{LsmdsConfig, Matrix};
use lmds_ose::runtime::{Backend, ComputeBackend};
use lmds_ose::strdist::Euclidean;
use lmds_ose::util::bench::{bench, BenchConfig, BenchResult};
use lmds_ose::util::json::Json;
use lmds_ose::util::prng::Rng;

struct Row {
    result: BenchResult,
    l: usize,
    iters: usize,
    stress: f64,
}

struct Report {
    rows: Vec<Row>,
}

impl Report {
    fn write(&self, backend_name: &str) {
        let path = std::env::var("LMDS_BENCH_JSON")
            .unwrap_or_else(|_| "BENCH_pr4.json".to_string());
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                Json::obj(vec![
                    ("name", Json::Str(row.result.name.clone())),
                    ("median_s", Json::Num(row.result.median_s)),
                    ("mad_s", Json::Num(row.result.mad_s)),
                    ("mean_s", Json::Num(row.result.mean_s)),
                    ("min_s", Json::Num(row.result.min_s)),
                    ("iters", Json::Num(row.result.iters as f64)),
                    ("l", Json::Num(row.l as f64)),
                    ("solve_iters", Json::Num(row.iters as f64)),
                    ("stress", Json::Num(row.stress)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::Str("bench_base".into())),
            ("backend", Json::Str(backend_name.into())),
            ("results", Json::Arr(rows)),
        ]);
        match std::fs::write(&path, doc.to_string_pretty()) {
            Ok(()) => println!("\nwrote {} results to {path}", self.rows.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

/// Both subjects run the production solve loop
/// (`coordinator::embedder::lsmds_landmarks_config`, no trailing O(L^2)
/// exact-stress pass); quality is scored separately via pair sampling so
/// the timed region is the solve alone.
fn solve_divide<S: DeltaSource + ?Sized>(
    source: &S,
    lcfg: &LsmdsConfig,
    dcfg: &DivideConfig,
    backend: &Backend,
) -> Matrix {
    divide_solve_with(source, lcfg.dim, dcfg, lcfg.seed, |b, sub| {
        let mut c = lcfg.clone();
        c.seed = block_seed(lcfg.seed, b as u64);
        lsmds_landmarks_config(sub, &c, backend)
    })
    .unwrap()
    .config
}

fn main() {
    lmds_ose::util::logging::init();
    let quick_mode = std::env::var("LMDS_BENCH_QUICK").is_ok();
    let dim = 7usize; // paper Sec. 5.3
    let solve_iters = if quick_mode { 20 } else { 60 };
    let sizes: Vec<usize> =
        if quick_mode { vec![2000, 10_000] } else { vec![2000, 10_000, 50_000] };
    // one measured sample for the multi-second subjects; the 2k subjects
    // are cheap enough for a few
    let one = BenchConfig {
        warmup: std::time::Duration::ZERO,
        measure: std::time::Duration::ZERO,
        max_iters: 1,
        min_iters: 1,
    };
    let few = BenchConfig {
        warmup: std::time::Duration::ZERO,
        measure: std::time::Duration::from_secs(2),
        max_iters: 3,
        min_iters: if quick_mode { 1 } else { 2 },
    };
    let backend = Backend::native();
    let mut report = Report { rows: Vec::new() };
    let stress_pairs = 200_000usize;

    for &l in &sizes {
        let blocks = if l >= 50_000 { 16 } else { 8 };
        let anchors = auto_anchors(l, dim);
        let mut rng = Rng::new(0xBA5E ^ l as u64);
        let points = Matrix::random_normal(&mut rng, l, dim, 1.0);
        let source = PointsDelta { points: &points };
        let lcfg = LsmdsConfig {
            dim,
            max_iters: solve_iters,
            rel_tol: 0.0, // fixed work: comparable wall-clock across solvers
            seed: 7,
            ..Default::default()
        };
        let dcfg = DivideConfig { blocks, anchors };
        let cfg = if l <= 2000 { &few } else { &one };
        println!(
            "\n== base solve L={l} K={dim} T={solve_iters} \
             (divide: B={blocks}, A={anchors}) =="
        );

        // Monolithic: needs the materialised L x L matrix. At 50k that is
        // 10 GB of f32 — out of reach by design, which is the point.
        let mono = if l < 50_000 {
            let refs: Vec<&[f32]> = (0..l).map(|i| points.row(i)).collect();
            let delta = full_matrix(&refs, &Euclidean);
            let mut last = Matrix::zeros(0, 0);
            let r = bench(&format!("base monolithic L={l} T={solve_iters}"), cfg, || {
                last = lsmds_landmarks_config(&delta, &lcfg, &backend).unwrap();
            });
            let stress = sampled_normalized_stress(&source, &last, stress_pairs, 3);
            println!("{}  (sampled stress {stress:.4})", r.report());
            report.rows.push(Row { result: r.clone(), l, iters: solve_iters, stress });
            Some(r)
        } else {
            println!(
                "base monolithic L={l}: skipped \
                 (L x L matrix would be {:.1} GB)",
                (l * l * 4) as f64 / 1e9
            );
            None
        };

        let mut last = Matrix::zeros(0, 0);
        let r = bench(
            &format!("base divide B={blocks} A={anchors} L={l} T={solve_iters}"),
            cfg,
            || {
                last = solve_divide(&source, &lcfg, &dcfg, &backend);
            },
        );
        let stress = sampled_normalized_stress(&source, &last, stress_pairs, 3);
        match &mono {
            Some(m) => println!(
                "{}  (sampled stress {stress:.4}, speedup {:.2}x vs monolithic)",
                r.report(),
                m.median_s / r.median_s
            ),
            None => println!("{}  (sampled stress {stress:.4})", r.report()),
        }
        report.rows.push(Row { result: r, l, iters: solve_iters, stress });
    }

    report.write(backend.name());
}
