//! Open-loop network serving bench: the binary-protocol front door under
//! paced load. Per-connection sender threads fire delta queries at fixed
//! timestamps — open loop, so a slow server cannot slow the offered rate,
//! only grow the queue — while receiver threads drain reply frames. Tail
//! latency comes from the server-side bounded metrics histograms. Writes
//! a machine-readable JSON report for the CI perf trajectory.
//!
//!     cargo bench --bench bench_serve_net
//!
//! Env knobs:
//!   LMDS_BENCH_QUICK=1        smaller sweep (CI smoke)
//!   LMDS_BENCH_JSON=path.json where to write the report
//!                             (default BENCH_pr6.json in the CWD)
//!
//! The front door is Linux-only (poll(2) event loop); elsewhere the bench
//! writes a report marked `skipped` so CI artifact collection never finds
//! the file missing. Pacing rides on thread::sleep, so offered rates well
//! above ~1k q/s per connection degrade into catch-up bursts — fine for a
//! load generator, the aggregate rate still lands near the target.

use lmds_ose::util::json::Json;

const L: usize = 300;
const CONNS: usize = 4;

fn main() {
    lmds_ose::util::logging::init();
    let quick = std::env::var("LMDS_BENCH_QUICK").is_ok();
    let rows = net_load::run_sweep(quick);
    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_serve_net".into())),
        ("backend", Json::Str("native".into())),
        ("method", Json::Str("nn".into())),
        ("skipped", Json::Bool(rows.is_empty())),
        ("connections", Json::Num(CONNS as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = std::env::var("LMDS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_pr6.json".to_string());
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote net serving bench report to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

#[cfg(target_os = "linux")]
mod net_load {
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use lmds_ose::coordinator::error::CODE_OVERLOADED;
    use lmds_ose::coordinator::methods::BackendNn;
    use lmds_ose::coordinator::proto::{read_frame, write_frame};
    use lmds_ose::coordinator::{
        BatcherConfig, Frame, NetConfig, NetServer, Request, ServerBuilder,
    };
    use lmds_ose::nn::{MlpParams, MlpShape};
    use lmds_ose::runtime::Backend;
    use lmds_ose::strdist::Levenshtein;
    use lmds_ose::util::json::Json;
    use lmds_ose::util::prng::Rng;

    use super::{CONNS, L};

    pub fn run_sweep(quick: bool) -> Vec<Json> {
        let targets: &[u64] = if quick { &[500, 2000] } else { &[1000, 4000, 16000] };
        let secs = if quick { 2.0 } else { 5.0 };
        let mut rng = Rng::new(1);
        let params = MlpParams::init(
            &MlpShape { input: L, hidden: [256, 128, 64], output: 7 },
            &mut rng,
        );
        println!(
            "== net serving: open-loop load over the wire protocol \
             (MLP L={L}, {CONNS} connections, {secs}s per point) =="
        );
        targets.iter().map(|&t| run_one(&params, t, secs)).collect()
    }

    fn run_one(params: &MlpParams, target: u64, secs: f64) -> Json {
        let landmarks: Vec<String> =
            (0..L).map(|i| format!("landmark{i:03}")).collect();
        let server = ServerBuilder::strings(
            landmarks,
            Arc::new(Levenshtein),
            BackendNn::replica_factory(Backend::native(), params.clone()),
        )
        .batcher(BatcherConfig {
            max_batch: 64,
            max_delay: Duration::from_micros(500),
            queue_cap: 4096,
            frontend_threads: 2,
            replicas: 4,
        })
        .build()
        .expect("valid server configuration");
        let h = server.handle();
        let front = NetServer::start(
            Arc::new(h.clone()),
            NetConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .expect("front door starts");
        let addr = front.local_addr();

        let mut rng = Rng::new(0x9e75);
        let delta: Vec<f32> = (0..L).map(|_| rng.next_f32() * 5.0).collect();
        // warm the executors so the sweep measures steady state
        for _ in 0..64 {
            h.submit(Request::delta(delta.clone())).recv().unwrap();
        }

        let per_conn = ((target as f64 * secs) as u64 / CONNS as u64).max(1);
        let interval_s = CONNS as f64 / target as f64;
        let completed = AtomicU64::new(0);
        let shed = AtomicU64::new(0);
        let errors = AtomicU64::new(0);
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..CONNS {
                let tx = TcpStream::connect(addr).expect("connect");
                tx.set_nodelay(true).ok();
                let rx = tx.try_clone().expect("clone stream");
                rx.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                let delta = delta.clone();
                scope.spawn(move || {
                    // open loop: query i goes out at t0 + i * interval,
                    // never gated on replies
                    let mut tx = tx;
                    let start = Instant::now();
                    for i in 0..per_conn {
                        let due =
                            start + Duration::from_secs_f64(i as f64 * interval_s);
                        let wait = due.saturating_duration_since(Instant::now());
                        if !wait.is_zero() {
                            std::thread::sleep(wait);
                        }
                        let f = Frame::QueryDelta { id: i, delta: delta.clone() };
                        write_frame(&mut tx, &f).expect("send query");
                    }
                });
                let (completed, shed, errors) = (&completed, &shed, &errors);
                scope.spawn(move || {
                    let mut rx = rx;
                    // every query draws exactly one reply: a result, or a
                    // load-shed / error frame
                    for _ in 0..per_conn {
                        match read_frame(&mut rx).expect("reply") {
                            Frame::Result { .. } => {
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Frame::Error { code, .. } if code == CODE_OVERLOADED => {
                                shed.fetch_add(1, Ordering::Relaxed);
                            }
                            _ => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let snap = h.metrics.snapshot();
        front.shutdown();
        drop(h);
        server.shutdown();

        let sent = per_conn * CONNS as u64;
        let done = completed.load(Ordering::Relaxed);
        let qps = done as f64 / wall;
        println!(
            "target {target:6} q/s -> {qps:6.0} q/s served | p50 {:.3}ms \
             p99 {:.3}ms | sent {sent}, shed {}, errors {}",
            snap.p50_s * 1e3,
            snap.p99_s * 1e3,
            shed.load(Ordering::Relaxed),
            errors.load(Ordering::Relaxed),
        );
        Json::obj(vec![
            ("qps_target", Json::Num(target as f64)),
            ("qps_achieved", Json::Num(qps)),
            ("sent", Json::Num(sent as f64)),
            ("completed", Json::Num(done as f64)),
            ("shed", Json::Num(shed.load(Ordering::Relaxed) as f64)),
            ("errors", Json::Num(errors.load(Ordering::Relaxed) as f64)),
            ("p50_s", Json::Num(snap.p50_s)),
            ("p95_s", Json::Num(snap.p95_s)),
            ("p99_s", Json::Num(snap.p99_s)),
        ])
    }
}

#[cfg(not(target_os = "linux"))]
mod net_load {
    use lmds_ose::util::json::Json;

    pub fn run_sweep(_quick: bool) -> Vec<Json> {
        println!("net serving bench skipped: the front door requires Linux");
        Vec::new()
    }
}
