//! Out-of-core end-to-end benchmark: write an N-record corpus to disk,
//! then run BOTH pipeline stages — divide-and-conquer base solve and the
//! streamed OSE pass — against it, at an N whose full N x N delta matrix
//! could not exist in RAM (N = 50k ⇒ 10 GB; the full run adds N = 200k
//! ⇒ 160 GB). A tracking allocator measures the *actual* peak heap of
//! the embed, which is asserted (and reported) against the bounded
//! budget O(cache + L² + stream chunks + N·K).
//!
//!     cargo bench --bench bench_outofcore
//!
//! Env knobs:
//!   LMDS_BENCH_QUICK=1        CI smoke: N = 50k only, random landmarks
//!   LMDS_BENCH_JSON=path.json report path (default BENCH_pr5.json)
//!
//! The table is opened through the pread backend so the block cache (and
//! therefore the corpus residency) is heap-allocated where the tracking
//! allocator can see it — the honest configuration for a memory claim.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use lmds_ose::coordinator::embedder::{
    embed_corpus, BaseSolver, OseBackend, PipelineConfig,
};
use lmds_ose::data::source::{CorpusWriter, ObjectTable, TableDelta};
use lmds_ose::data::synthetic::gaussian_clusters;
use lmds_ose::mds::divide::sampled_normalized_stress;
use lmds_ose::mds::{LandmarkMethod, LsmdsConfig};
use lmds_ose::runtime::{Backend, ComputeBackend};
use lmds_ose::strdist::Euclidean;
use lmds_ose::util::json::Json;
use lmds_ose::util::prng::Rng;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

struct TrackingAlloc;

unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc_zeroed(layout) };
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) };
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
            let live = LIVE.fetch_add(new_size, Ordering::Relaxed) + new_size;
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }
}

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

struct Subject {
    n: usize,
    l: usize,
    blocks: usize,
    landmark_method: LandmarkMethod,
}

struct Row {
    name: String,
    n: usize,
    l: usize,
    full_delta_gb: f64,
    write_s: f64,
    wall_s: f64,
    select_s: f64,
    base_s: f64,
    stream_s: f64,
    peak_mb: f64,
    budget_mb: f64,
    within_budget: bool,
    stress: f64,
}

fn run_subject(s: &Subject, backend: &Backend, cache_budget: usize) -> Row {
    let dim = 8usize;
    let k = 7usize;
    let chunk = 1024usize;

    let mut path = std::env::temp_dir();
    path.push(format!("lmds_bench_ooc_{}_{}", s.n, std::process::id()));

    // corpus write (streamed batches; reported separately from the embed)
    let t0 = std::time::Instant::now();
    {
        let mut w = CorpusWriter::create_vectors(&path, dim).unwrap();
        let mut rng = Rng::new(0xBE2C ^ s.n as u64);
        let mut written = 0usize;
        while written < s.n {
            let batch = (s.n - written).min(8192);
            for row in gaussian_clusters(&mut rng, batch, dim, 16, 1.0) {
                w.push_vector(&row).unwrap();
            }
            written += batch;
        }
        w.finish().unwrap();
    }
    let write_s = t0.elapsed().as_secs_f64();

    let cfg = PipelineConfig {
        dim: k,
        landmarks: s.l,
        landmark_method: s.landmark_method,
        backend: OseBackend::Opt,
        lsmds: LsmdsConfig { dim: k, max_iters: 60, ..Default::default() },
        base_solver: BaseSolver::DivideConquer { blocks: s.blocks, anchors: 0 },
        stream_chunk: Some(chunk),
        ose_steps: Some(8),
        ..Default::default()
    };

    let budget_bytes = cache_budget
        + s.l * s.l * 4 * 2      // divide sub-matrices / landmark config
        + 2 * chunk * s.l * 4    // in-flight stream blocks
        + s.n * k * 4            // output
        + s.n * 8                // rest-index bookkeeping
        + (16 << 20); // slack

    let baseline = LIVE.load(Ordering::Relaxed);
    PEAK.store(baseline, Ordering::Relaxed);
    let t0 = std::time::Instant::now();
    let table = ObjectTable::open_pread(&path, cache_budget).unwrap();
    let source = TableDelta::vectors(&table, &Euclidean).unwrap();
    let result = embed_corpus(&source, &cfg, backend).unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    let peak = PEAK.load(Ordering::Relaxed).saturating_sub(baseline);

    assert!(result.coords.data.iter().all(|v| v.is_finite()));
    let stress =
        sampled_normalized_stress(&source, &result.coords, 200_000, 3);
    std::fs::remove_file(&path).ok();

    let t = &result.timings;
    Row {
        name: format!("outofcore embed N={} L={} ({:?})", s.n, s.l, s.landmark_method),
        n: s.n,
        l: s.l,
        full_delta_gb: (s.n as f64) * (s.n as f64) * 4.0 / 1e9,
        write_s,
        wall_s,
        select_s: t.select_s,
        base_s: t.delta_ll_s + t.lsmds_s,
        stream_s: t.delta_ml_s.max(t.ose_s),
        peak_mb: peak as f64 / 1e6,
        budget_mb: budget_bytes as f64 / 1e6,
        within_budget: peak < budget_bytes,
        stress,
    }
}

fn main() {
    lmds_ose::util::logging::init();
    let quick_mode = std::env::var("LMDS_BENCH_QUICK").is_ok();
    let backend = Backend::native();
    let cache_budget = 32 << 20;

    // N = 50k: the full N x N delta matrix would be 10 GB (> 8 GB), and
    // even the N x L out-of-sample block is 200 MB — neither exists here.
    let mut subjects = vec![Subject {
        n: 50_000,
        l: 1000,
        blocks: 8,
        landmark_method: if quick_mode {
            LandmarkMethod::Random
        } else {
            LandmarkMethod::Fps
        },
    }];
    if !quick_mode {
        subjects.push(Subject {
            n: 200_000,
            l: 1000,
            blocks: 16,
            landmark_method: LandmarkMethod::Random,
        });
    }

    let mut rows = Vec::new();
    for s in &subjects {
        println!(
            "\n== out-of-core embed N={} L={} (full delta would be {:.1} GB) ==",
            s.n,
            s.l,
            (s.n as f64) * (s.n as f64) * 4.0 / 1e9
        );
        let row = run_subject(s, &backend, cache_budget);
        println!(
            "{}: wall {:.2}s (write {:.2}s | select {:.2}s | base {:.2}s | \
             stream {:.2}s)",
            row.name, row.wall_s, row.write_s, row.select_s, row.base_s, row.stream_s
        );
        println!(
            "   peak heap {:.1} MB vs budget {:.1} MB ({}) | sampled stress {:.4}",
            row.peak_mb,
            row.budget_mb,
            if row.within_budget { "WITHIN" } else { "EXCEEDED" },
            row.stress
        );
        assert!(
            row.within_budget,
            "peak heap {:.1} MB exceeded the bounded budget {:.1} MB",
            row.peak_mb,
            row.budget_mb
        );
        rows.push(row);
    }

    let path = std::env::var("LMDS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_pr5.json".to_string());
    let results: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("n", Json::Num(r.n as f64)),
                ("l", Json::Num(r.l as f64)),
                ("full_delta_gb", Json::Num(r.full_delta_gb)),
                ("write_s", Json::Num(r.write_s)),
                ("wall_s", Json::Num(r.wall_s)),
                ("select_s", Json::Num(r.select_s)),
                ("base_s", Json::Num(r.base_s)),
                ("stream_s", Json::Num(r.stream_s)),
                ("peak_mb", Json::Num(r.peak_mb)),
                ("budget_mb", Json::Num(r.budget_mb)),
                ("within_budget", Json::Bool(r.within_budget)),
                ("sampled_stress", Json::Num(r.stress)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_outofcore".into())),
        ("backend", Json::Str(backend.name().into())),
        ("results", Json::Arr(results)),
    ]);
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("\nwrote {} results to {path}", rows.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
