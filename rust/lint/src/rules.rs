//! The six project-invariant rules enforced by `lmds-lint`.
//!
//! Every rule works on the aligned [`LineView`] views produced by
//! [`crate::scan::scan`], so substring matches never fire inside
//! comments or string literals. The rules, their diagnostics tags, and
//! the override syntax are documented for humans in
//! `docs/ARCHITECTURE.md` ("Static analysis & sanitizers"); this module
//! is the single source of truth for the machine behaviour.

use std::fmt;

use crate::scan::{contains_word, LineView};

/// One diagnostic: `path:line: [rule] message`.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative path with `/` separators.
    pub path: String,
    /// 1-based source line.
    pub line: usize,
    /// Stable rule tag (`unsafe-audit`, `no-panic`, `wire-stability`,
    /// `config-drift`, `doc-link`, `style`) — the CI self-test greps for
    /// these.
    pub rule: &'static str,
    /// Human-readable explanation with the fix path.
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// The machine-readable per-file exception list (`rust/lint/lint-allow.txt`):
/// one `<path> <rule> <reason…>` entry per line, `#` comments allowed. An
/// entry without a reason is a parse error — exceptions must be argued.
pub struct Allowlist {
    entries: Vec<(String, String)>,
}

impl Allowlist {
    /// An allowlist with no entries (used by tests).
    pub fn empty() -> Self {
        Allowlist { entries: Vec::new() }
    }

    /// Parse the allowlist file contents; malformed lines are hard errors.
    // LINT-ALLOW(style): dependency-free tool; the one error path goes to stderr.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut entries = Vec::new();
        for (no, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let (Some(path), Some(rule)) = (parts.next(), parts.next()) else {
                return Err(format!(
                    "lint-allow.txt:{}: malformed entry; expected `<path> <rule> <reason>`",
                    no + 1
                ));
            };
            if parts.next().is_none() {
                return Err(format!(
                    "lint-allow.txt:{}: entry for {path} needs a reason after the rule name",
                    no + 1
                ));
            }
            entries.push((path.to_string(), rule.to_string()));
        }
        Ok(Allowlist { entries })
    }

    /// True when `path` carries an exception for `rule`.
    pub fn is_allowed(&self, path: &str, rule: &str) -> bool {
        self.entries.iter().any(|(p, r)| p == path && r == rule)
    }
}

fn ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// `// LINT-ALLOW(<tag>): <reason>` on the same line or the line above.
fn has_allow(lines: &[LineView], i: usize, tag: &str) -> bool {
    let pat = format!("LINT-ALLOW({tag}):");
    lines[i].comment.contains(&pat) || (i > 0 && lines[i - 1].comment.contains(&pat))
}

/// Per-line map of `#[cfg(test)]` item spans, found by brace counting on
/// the code views from each `#[cfg(…test…)]` attribute (a top-level `;`
/// before any `{` bounds attributes on brace-less items). `not(test)`
/// spans are production code and are deliberately NOT marked.
pub fn test_spans(lines: &[LineView]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let code = &lines[i].code;
        let is_cfg_test = code.trim_start().starts_with("#[cfg(")
            && contains_word(code, "test")
            && !code.contains("not(test)");
        if !is_cfg_test {
            i += 1;
            continue;
        }
        let mut depth = 0i32;
        let mut started = false;
        let mut end = i;
        'span: for (j, line) in lines.iter().enumerate().skip(i) {
            for c in line.code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => {
                        depth -= 1;
                        if started && depth == 0 {
                            end = j;
                            break 'span;
                        }
                    }
                    ';' if !started && depth == 0 => {
                        end = j;
                        break 'span;
                    }
                    _ => {}
                }
            }
            end = j;
        }
        for flag in in_test.iter_mut().take(end + 1).skip(i) {
            *flag = true;
        }
        i = end + 1;
    }
    in_test
}

// ---------------------------------------------------------------------------
// Rule 1: unsafe-audit
// ---------------------------------------------------------------------------

fn has_safety(comment: &str) -> bool {
    comment.contains("SAFETY:") || comment.contains("# Safety")
}

/// Walk upward from the `unsafe` site through the contiguous run of
/// blank lines, attributes, and comment lines; true if the site's own
/// line or any comment in that run carries `SAFETY:` or a `# Safety`
/// doc heading. The first real code line ends the run, so two adjacent
/// `unsafe` lines each need their own annotation.
fn safety_annotated(lines: &[LineView], i: usize) -> bool {
    const MAX_WALK: usize = 40;
    if has_safety(&lines[i].comment) {
        return true;
    }
    let mut j = i;
    for _ in 0..MAX_WALK {
        if j == 0 {
            return false;
        }
        j -= 1;
        let l = &lines[j];
        if has_safety(&l.comment) {
            return true;
        }
        let code_t = l.code.trim();
        let is_attr = code_t.starts_with("#[") || code_t.starts_with("#!");
        if code_t.is_empty() || is_attr {
            continue;
        }
        return false;
    }
    false
}

/// Rule 1: every `unsafe` keyword (block, fn, impl) needs a preceding
/// `// SAFETY:` comment or `# Safety` doc section, unless the whole file
/// carries an `unsafe-audit` allowlist entry.
pub fn rule_unsafe_audit(path: &str, lines: &[LineView], allow: &Allowlist) -> Vec<Finding> {
    if allow.is_allowed(path, "unsafe-audit") {
        return Vec::new();
    }
    let mut findings = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        if !contains_word(&l.code, "unsafe") {
            continue;
        }
        if safety_annotated(lines, i) {
            continue;
        }
        findings.push(Finding {
            path: path.to_string(),
            line: i + 1,
            rule: "unsafe-audit",
            msg: "`unsafe` without a preceding `// SAFETY:` comment (or `# Safety` doc \
                  section); justify it or add a rust/lint/lint-allow.txt entry"
                .to_string(),
        });
    }
    findings
}

// ---------------------------------------------------------------------------
// Rule 2: no-panic serving paths
// ---------------------------------------------------------------------------

/// Files on the serving request path: a panic here kills an executor or
/// drops a connection, so these must return typed `ServeError`s.
pub const SERVING_PATHS: &[&str] = &[
    "rust/src/coordinator/server.rs",
    "rust/src/coordinator/shard.rs",
    "rust/src/coordinator/net.rs",
    "rust/src/coordinator/proto.rs",
    "rust/src/coordinator/error.rs",
    "rust/src/ose/pipeline.rs",
];

const BANNED_PANICS: &[&str] = &[".unwrap()", ".expect(", "panic!", "todo!", "unimplemented!"];

fn banned_at(code: &str, pat: &str) -> bool {
    code.match_indices(pat).any(|(idx, _)| {
        if pat.starts_with('.') {
            return true;
        }
        let prev = code[..idx].chars().next_back();
        !matches!(prev, Some(p) if ident_char(p))
    })
}

/// Rule 2: `.unwrap()` / `.expect(` / `panic!` / `todo!` /
/// `unimplemented!` are forbidden in [`SERVING_PATHS`] outside
/// `#[cfg(test)]` spans; `// LINT-ALLOW(panic): <reason>` overrides a
/// single site.
pub fn rule_no_panic(path: &str, lines: &[LineView]) -> Vec<Finding> {
    if !SERVING_PATHS.contains(&path) {
        return Vec::new();
    }
    let in_test = test_spans(lines);
    let mut findings = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        for pat in BANNED_PANICS {
            if !banned_at(&l.code, pat) {
                continue;
            }
            if has_allow(lines, i, "panic") {
                continue;
            }
            findings.push(Finding {
                path: path.to_string(),
                line: i + 1,
                rule: "no-panic",
                msg: format!(
                    "`{pat}` on a serving path; return a typed ServeError or annotate \
                     `// LINT-ALLOW(panic): <reason>`"
                ),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Rule 3: style bans
// ---------------------------------------------------------------------------

/// True when some `Result<…>` on the line has `String` as its full
/// second (error) type argument. `Result<Vec<String>, E>` does not
/// match; `Result<(), String>` does.
fn result_err_is_string(code: &str) -> bool {
    for (idx, _) in code.match_indices("Result<") {
        let prev = code[..idx].chars().next_back();
        if matches!(prev, Some(p) if ident_char(p)) {
            continue;
        }
        let args = &code[idx + "Result<".len()..];
        let mut depth = 1i32;
        let mut top_comma = None;
        let mut close = None;
        for (j, c) in args.char_indices() {
            match c {
                '<' => depth += 1,
                '>' => {
                    depth -= 1;
                    if depth == 0 {
                        close = Some(j);
                        break;
                    }
                }
                ',' if depth == 1 && top_comma.is_none() => top_comma = Some(j),
                _ => {}
            }
        }
        if let (Some(cm), Some(cl)) = (top_comma, close) {
            if args[cm + 1..cl].trim() == "String" {
                return true;
            }
        }
    }
    false
}

/// Rule 5 ("style"): no `Result<_, String>` in `pub` signatures (typed
/// errors only) and no `std::process::exit` outside a `main.rs`.
/// `// LINT-ALLOW(style): <reason>` overrides a single site.
pub fn rule_style(path: &str, lines: &[LineView]) -> Vec<Finding> {
    let basename = path.rsplit('/').next().unwrap_or(path);
    let in_test = test_spans(lines);
    let mut findings = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        if in_test[i] || has_allow(lines, i, "style") {
            continue;
        }
        if contains_word(&l.code, "pub") && result_err_is_string(&l.code) {
            findings.push(Finding {
                path: path.to_string(),
                line: i + 1,
                rule: "style",
                msg: "public API uses Result<_, String>; define a typed error enum \
                      (see coordinator::error) or annotate `// LINT-ALLOW(style): <reason>`"
                    .to_string(),
            });
        }
        if l.code.contains("process::exit") && basename != "main.rs" {
            findings.push(Finding {
                path: path.to_string(),
                line: i + 1,
                rule: "style",
                msg: "std::process::exit outside main.rs; bubble the error up to the \
                      binary entry point instead"
                    .to_string(),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Rule 4: wire-stability
// ---------------------------------------------------------------------------

/// One `const NAME: TY = VALUE;` extracted from a code view.
pub struct WireConst {
    /// Constant name as written in source.
    pub name: String,
    /// Declared type (`u16`, `u8`, `usize`).
    pub ty: String,
    /// Initialiser expression, verbatim (`6`, `1 << 20`).
    pub value: String,
    /// 1-based source line.
    pub line: usize,
}

/// Extract `[pub] const <prefix>…: TY = VALUE;` declarations whose name
/// starts with one of `prefixes`.
pub fn extract_wire_consts(lines: &[LineView], prefixes: &[&str]) -> Vec<WireConst> {
    let mut out = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        let t = l.code.trim();
        let t = t.strip_prefix("pub ").unwrap_or(t);
        let Some(rest) = t.strip_prefix("const ") else {
            continue;
        };
        let Some((name, rest)) = rest.split_once(':') else {
            continue;
        };
        let name = name.trim();
        if !prefixes.iter().any(|p| name.starts_with(p)) {
            continue;
        }
        let Some((ty, rest)) = rest.split_once('=') else {
            continue;
        };
        let Some((value, _)) = rest.split_once(';') else {
            continue;
        };
        out.push(WireConst {
            name: name.to_string(),
            ty: ty.trim().to_string(),
            value: value.trim().to_string(),
            line: i + 1,
        });
    }
    out
}

/// Rule 3 ("wire-stability"): the `ServeError` u16 codes, the proto
/// frame-type tags, and `MAX_FRAME` must match the committed golden
/// table exactly — silent renumbering is a wire-ABI break.
pub fn rule_wire_stability(
    error_path: &str,
    error_lines: &[LineView],
    proto_path: &str,
    proto_lines: &[LineView],
    golden_text: &str,
    golden_path: &str,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut extracted: Vec<(String, WireConst, String)> = Vec::new();
    for c in extract_wire_consts(error_lines, &["CODE_"]) {
        extracted.push((format!("error.{}", c.name), c, error_path.to_string()));
    }
    for c in extract_wire_consts(proto_lines, &["TYPE_", "MAX_FRAME"]) {
        extracted.push((format!("proto.{}", c.name), c, proto_path.to_string()));
    }

    let mut golden: Vec<(String, String, String, usize)> = Vec::new();
    for (no, raw) in golden_text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(name), Some(ty)) = (parts.next(), parts.next()) else {
            findings.push(Finding {
                path: golden_path.to_string(),
                line: no + 1,
                rule: "wire-stability",
                msg: "malformed golden entry; expected `<name> <type> <value>`".to_string(),
            });
            continue;
        };
        let value = parts.collect::<Vec<_>>().join(" ");
        if value.is_empty() {
            findings.push(Finding {
                path: golden_path.to_string(),
                line: no + 1,
                rule: "wire-stability",
                msg: "malformed golden entry; expected `<name> <type> <value>`".to_string(),
            });
            continue;
        }
        golden.push((name.to_string(), ty.to_string(), value, no + 1));
    }

    for (name, c, path) in &extracted {
        match golden.iter().find(|g| &g.0 == name) {
            None => findings.push(Finding {
                path: path.clone(),
                line: c.line,
                rule: "wire-stability",
                msg: format!(
                    "wire constant {name} is not in the golden table; add it to {golden_path}"
                ),
            }),
            Some((_, gty, gval, _)) => {
                if gty != &c.ty || gval != &c.value {
                    findings.push(Finding {
                        path: path.clone(),
                        line: c.line,
                        rule: "wire-stability",
                        msg: format!(
                            "wire constant {name}: source says `{}: {}` but the golden table \
                             says `{gty}: {gval}` — renumbering breaks deployed clients; if \
                             deliberate, update {golden_path}",
                            c.ty, c.value
                        ),
                    });
                }
            }
        }
    }
    for (name, _, _, gline) in &golden {
        if !extracted.iter().any(|(n, _, _)| n == name) {
            findings.push(Finding {
                path: golden_path.to_string(),
                line: *gline,
                rule: "wire-stability",
                msg: format!("golden wire constant {name} no longer exists in source"),
            });
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Rule 5: config/docs drift
// ---------------------------------------------------------------------------

/// Extract config keys from the `stripped` views of `config.rs`: the
/// string arguments of `json.get("…")` and `usize_of(json, "…")`.
/// (The CLI layer reuses the same keys in kebab-case, so the JSON
/// accessors are the single source of truth.)
pub fn extract_config_keys(lines: &[LineView]) -> Vec<(String, usize)> {
    const PATS: &[&str] = &["json.get(\"", "usize_of(json, \""];
    let mut out: Vec<(String, usize)> = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        for pat in PATS {
            for (idx, _) in l.stripped.match_indices(pat) {
                let rest = &l.stripped[idx + pat.len()..];
                let Some(end) = rest.find('"') else {
                    continue;
                };
                let key = &rest[..end];
                if !key.is_empty() && out.iter().all(|(k, _)| k != key) {
                    out.push((key.to_string(), i + 1));
                }
            }
        }
    }
    out
}

/// Rule 4 ("config-drift"): every config key read in
/// `coordinator/config.rs` must appear backtick-quoted in both the
/// README flag table and `docs/ARCHITECTURE.md`.
pub fn rule_config_drift(
    config_path: &str,
    config_lines: &[LineView],
    readme_text: &str,
    arch_text: &str,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (key, line) in extract_config_keys(config_lines) {
        let quoted = format!("`{key}`");
        for (doc, text) in [("README.md", readme_text), ("docs/ARCHITECTURE.md", arch_text)] {
            if !text.contains(&quoted) {
                findings.push(Finding {
                    path: config_path.to_string(),
                    line,
                    rule: "config-drift",
                    msg: format!("config key `{key}` is not documented in {doc}"),
                });
            }
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Rule 6: doc-link
// ---------------------------------------------------------------------------

/// Lexically join `base` (the linking doc's directory, repo-relative,
/// `""` for the repo root) with a relative `target`, normalising `.` and
/// `..` segments. `None` when the path escapes the repo root — such
/// links point out of tree and are not checkable.
fn resolve_relative(base: &str, target: &str) -> Option<String> {
    let mut parts: Vec<&str> =
        if base.is_empty() { Vec::new() } else { base.split('/').collect() };
    for seg in target.split('/') {
        match seg {
            "" | "." => {}
            ".." => {
                parts.pop()?;
            }
            s => parts.push(s),
        }
    }
    Some(parts.join("/"))
}

fn path_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || matches!(c, '/' | '.' | '_' | '-')
}

/// Targets of inline markdown links `[text](target)` on one line.
/// External (`scheme://`, `mailto:`) and fragment-only (`#…`) targets
/// are dropped; a `#fragment` suffix and an optional `"title"` after the
/// path are stripped.
fn inline_link_targets(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    for (idx, _) in line.match_indices("](") {
        let rest = &line[idx + 2..];
        let Some(end) = rest.find(')') else {
            continue;
        };
        let Some(raw) = rest[..end].split_whitespace().next() else {
            continue;
        };
        if raw.contains("://") || raw.starts_with("mailto:") || raw.starts_with('#') {
            continue;
        }
        let target = raw.split('#').next().unwrap_or("");
        if !target.is_empty() {
            out.push(target.to_string());
        }
    }
    out
}

/// Bare `docs/*.md` path mentions in prose (outside link syntax), e.g.
/// ``see `docs/QUERY_PATH.md` ``. Always repo-root-relative.
fn bare_doc_mentions(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    for (idx, _) in line.match_indices("docs/") {
        // a path character just before means this is a longer path
        // (`../docs/…`, `foo/docs/…`) handled by the inline extractor
        if matches!(line[..idx].chars().next_back(), Some(p) if path_char(p)) {
            continue;
        }
        let rest = &line[idx..];
        let len = rest.chars().take_while(|c| path_char(*c)).count();
        let mut token = &rest[..len];
        // trim trailing punctuation the char class over-captures (`.`,
        // `..`) down to the `.md` suffix
        while !token.is_empty() && !token.ends_with(".md") {
            token = &token[..token.len() - 1];
        }
        if token.len() > "docs/".len() {
            out.push(token.to_string());
        }
    }
    out
}

/// Rule 6 ("doc-link"): every relative `[text](path)` link and every
/// bare `docs/*.md` mention in the checked documentation set must point
/// at a file that exists in the tree. Inline links are accepted if they
/// resolve either relative to the linking doc's directory or from the
/// repo root (both conventions appear in the tree); bare mentions are
/// repo-root-relative. A line containing `LINT-ALLOW(doc-link)` is
/// skipped (HTML-comment form: `<!-- LINT-ALLOW(doc-link): reason -->`).
pub fn rule_doc_links(
    doc_path: &str,
    doc_text: &str,
    exists: &dyn Fn(&str) -> bool,
) -> Vec<Finding> {
    let base = match doc_path.rfind('/') {
        Some(i) => &doc_path[..i],
        None => "",
    };
    let mut findings = Vec::new();
    for (i, line) in doc_text.lines().enumerate() {
        if line.contains("LINT-ALLOW(doc-link)") {
            continue;
        }
        // target -> candidate resolutions; merged so an inline link and
        // a bare mention of the same path yield one diagnostic
        let mut cands: Vec<(String, Vec<String>)> = Vec::new();
        let merge = |cands: &mut Vec<(String, Vec<String>)>,
                     target: String,
                     res: Vec<String>| {
            match cands.iter_mut().find(|(t, _)| *t == target) {
                Some((_, existing)) => {
                    for r in res {
                        if !existing.contains(&r) {
                            existing.push(r);
                        }
                    }
                }
                None => cands.push((target, res)),
            }
        };
        for target in inline_link_targets(line) {
            let mut res = Vec::new();
            if let Some(p) = resolve_relative(base, &target) {
                res.push(p);
            }
            if let Some(p) = resolve_relative("", &target) {
                if !res.contains(&p) {
                    res.push(p);
                }
            }
            if res.is_empty() {
                continue; // escapes the repo root: out of tree, unchecked
            }
            merge(&mut cands, target, res);
        }
        for target in bare_doc_mentions(line) {
            let res = vec![target.clone()];
            merge(&mut cands, target, res);
        }
        for (target, res) in cands {
            if res.iter().any(|p| exists(p)) {
                continue;
            }
            findings.push(Finding {
                path: doc_path.to_string(),
                line: i + 1,
                rule: "doc-link",
                msg: format!(
                    "link target `{target}` does not exist in the tree; fix the \
                     path or annotate the line with \
                     `<!-- LINT-ALLOW(doc-link): <reason> -->`"
                ),
            });
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::scan;
    use std::path::Path;

    fn fixture(name: &str) -> Vec<LineView> {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        let src = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read fixture {}: {e}", path.display()));
        scan(&src)
    }

    fn manifest_relative(rel: &str) -> String {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join(rel);
        std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
    }

    // -- allowlist ----------------------------------------------------------

    #[test]
    fn allowlist_parses_and_matches() {
        let a = Allowlist::parse(
            "# comment\n\nrust/tests/x.rs unsafe-audit GlobalAlloc shim, delegates to System\n",
        )
        .unwrap();
        assert!(a.is_allowed("rust/tests/x.rs", "unsafe-audit"));
        assert!(!a.is_allowed("rust/tests/x.rs", "no-panic"));
        assert!(!a.is_allowed("rust/tests/y.rs", "unsafe-audit"));
    }

    #[test]
    fn allowlist_rejects_entry_without_reason() {
        assert!(Allowlist::parse("rust/tests/x.rs unsafe-audit\n").is_err());
        assert!(Allowlist::parse("just-a-path\n").is_err());
    }

    // -- unsafe-audit -------------------------------------------------------

    #[test]
    fn unsafe_audit_fires_on_fixture() {
        let lines = fixture("unsafe_missing_safety.rs");
        let f = rule_unsafe_audit("fixtures/unsafe_missing_safety.rs", &lines, &Allowlist::empty());
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "unsafe-audit"));
        // The fixture marks expected-finding lines with `MARK` comments.
        let marked: Vec<usize> = lines
            .iter()
            .enumerate()
            .filter(|(_, l)| l.comment.contains("MARK"))
            .map(|(i, _)| i + 1)
            .collect();
        let found: Vec<usize> = f.iter().map(|x| x.line).collect();
        assert_eq!(found, marked);
    }

    #[test]
    fn unsafe_audit_silent_on_annotated_fixture() {
        let lines = fixture("unsafe_annotated.rs");
        let f = rule_unsafe_audit("fixtures/unsafe_annotated.rs", &lines, &Allowlist::empty());
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn unsafe_audit_respects_allowlist() {
        let lines = fixture("unsafe_missing_safety.rs");
        let allow = Allowlist::parse("fixtures/unsafe_missing_safety.rs unsafe-audit test shim\n")
            .unwrap();
        assert!(rule_unsafe_audit("fixtures/unsafe_missing_safety.rs", &lines, &allow).is_empty());
    }

    #[test]
    fn adjacent_unsafe_impls_need_individual_comments() {
        let lines = scan(
            "// SAFETY: T is Send.\nunsafe impl<T: Send> Send for W<T> {}\nunsafe impl<T: Send> Sync for W<T> {}\n",
        );
        let f = rule_unsafe_audit("x.rs", &lines, &Allowlist::empty());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn doc_safety_section_counts_through_attributes() {
        let lines = scan(
            "/// Does things.\n///\n/// # Safety\n/// Caller checks avx2.\n#[target_feature(enable = \"avx2\")]\npub unsafe fn f() {}\n",
        );
        assert!(rule_unsafe_audit("x.rs", &lines, &Allowlist::empty()).is_empty());
    }

    #[test]
    fn safety_in_string_literal_does_not_count() {
        let lines = scan("let m = \"SAFETY: not a comment\";\nunsafe { op() };\n");
        let f = rule_unsafe_audit("x.rs", &lines, &Allowlist::empty());
        assert_eq!(f.len(), 1);
    }

    // -- no-panic -----------------------------------------------------------

    #[test]
    fn no_panic_fires_on_fixture() {
        let lines = fixture("panic_in_serving.rs");
        // The rule is path-gated; fixtures borrow a serving path name.
        let f = rule_no_panic("rust/src/coordinator/server.rs", &lines);
        let marked: Vec<usize> = lines
            .iter()
            .enumerate()
            .filter(|(_, l)| l.comment.contains("MARK"))
            .map(|(i, _)| i + 1)
            .collect();
        let found: Vec<usize> = f.iter().map(|x| x.line).collect();
        assert_eq!(found, marked, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "no-panic"));
    }

    #[test]
    fn no_panic_silent_on_clean_fixture() {
        let lines = fixture("panic_allowed.rs");
        let f = rule_no_panic("rust/src/coordinator/server.rs", &lines);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn no_panic_ignores_non_serving_files() {
        let lines = scan("fn f(v: Option<u8>) -> u8 {\n    v.unwrap()\n}\n");
        assert!(rule_no_panic("rust/src/mds/lsmds.rs", &lines).is_empty());
        assert_eq!(rule_no_panic("rust/src/coordinator/net.rs", &lines).len(), 1);
    }

    #[test]
    fn no_panic_skips_cfg_test_spans() {
        let src = concat!(
            "fn ok() {}\n\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() {\n",
            "        Some(1).unwrap();\n",
            "    }\n",
            "}\n"
        );
        assert!(rule_no_panic("rust/src/coordinator/proto.rs", &scan(src)).is_empty());
    }

    #[test]
    fn no_panic_does_not_match_unwrap_or_and_strings() {
        let src = concat!(
            "fn f(v: Option<u8>) -> u8 {\n",
            "    log(\"never .unwrap() here\");\n",
            "    v.unwrap_or(0)\n}\n"
        );
        assert!(rule_no_panic("rust/src/coordinator/proto.rs", &scan(src)).is_empty());
    }

    #[test]
    fn cfg_not_test_is_production_code() {
        let src = "#[cfg(not(test))]\nfn f(v: Option<u8>) -> u8 {\n    v.unwrap()\n}\n";
        assert_eq!(rule_no_panic("rust/src/coordinator/proto.rs", &scan(src)).len(), 1);
    }

    // -- style --------------------------------------------------------------

    #[test]
    fn style_fires_on_fixture() {
        let lines = fixture("style_bad.rs");
        let f = rule_style("fixtures/style_bad.rs", &lines);
        let marked: Vec<usize> = lines
            .iter()
            .enumerate()
            .filter(|(_, l)| l.comment.contains("MARK"))
            .map(|(i, _)| i + 1)
            .collect();
        let found: Vec<usize> = f.iter().map(|x| x.line).collect();
        assert_eq!(found, marked, "{f:?}");
    }

    #[test]
    fn result_string_matcher_is_precise() {
        assert!(result_err_is_string("pub fn f() -> Result<(), String> {"));
        assert!(result_err_is_string("pub type R = Result<Vec<u8>, String>;"));
        assert!(!result_err_is_string("pub fn f() -> Result<String, Error> {"));
        assert!(!result_err_is_string("pub fn f() -> Result<Vec<String>, Error> {"));
        assert!(!result_err_is_string("pub fn f() -> anyhow::Result<String> {"));
    }

    #[test]
    fn process_exit_allowed_only_in_main_rs() {
        let lines = scan("fn die() {\n    std::process::exit(2);\n}\n");
        assert_eq!(rule_style("rust/src/util/mod.rs", &lines).len(), 1);
        assert!(rule_style("rust/src/main.rs", &lines).is_empty());
        assert!(rule_style("rust/lint/src/main.rs", &lines).is_empty());
    }

    // -- wire-stability -----------------------------------------------------

    fn wire_findings(golden: &str) -> Vec<Finding> {
        let error_lines = scan(&manifest_relative("../src/coordinator/error.rs"));
        let proto_lines = scan(&manifest_relative("../src/coordinator/proto.rs"));
        rule_wire_stability(
            "rust/src/coordinator/error.rs",
            &error_lines,
            "rust/src/coordinator/proto.rs",
            &proto_lines,
            golden,
            "rust/lint/golden/wire_abi.txt",
        )
    }

    #[test]
    fn wire_golden_round_trips_against_source() {
        let golden = manifest_relative("golden/wire_abi.txt");
        let f = wire_findings(&golden);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn wire_renumbering_is_detected() {
        let golden = manifest_relative("golden/wire_abi.txt");
        let tampered = golden.replace("error.CODE_TIMEOUT u16 6", "error.CODE_TIMEOUT u16 60");
        let f = wire_findings(&tampered);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].msg.contains("CODE_TIMEOUT"));
    }

    #[test]
    fn wire_removed_const_is_detected() {
        let golden = manifest_relative("golden/wire_abi.txt");
        let extended = format!("{golden}proto.TYPE_GONE u8 9\n");
        let f = wire_findings(&extended);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].msg.contains("no longer exists"));
    }

    #[test]
    fn wire_extractor_reads_consts() {
        let lines = scan("/// Doc.\npub const CODE_X: u16 = 3;\nconst OTHER: u8 = 1;\n");
        let consts = extract_wire_consts(&lines, &["CODE_"]);
        assert_eq!(consts.len(), 1);
        assert_eq!(consts[0].name, "CODE_X");
        assert_eq!(consts[0].ty, "u16");
        assert_eq!(consts[0].value, "3");
        assert_eq!(consts[0].line, 2);
    }

    // -- config-drift -------------------------------------------------------

    #[test]
    fn config_keys_extracted_from_strings_not_comments() {
        let src = concat!(
            "fn apply(json: &Json) {\n",
            "    // json.get(\"ghost\") stays undocumented\n",
            "    let _ = json.get(\"dim\");\n",
            "    let _ = usize_of(json, \"landmarks\");\n}\n"
        );
        let keys = extract_config_keys(&scan(src));
        let names: Vec<&str> = keys.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, ["dim", "landmarks"]);
    }

    #[test]
    fn config_drift_reports_each_missing_doc() {
        let src = concat!(
            "fn apply(json: &Json) {\n",
            "    let _ = json.get(\"alpha\");\n",
            "    let _ = json.get(\"beta\");\n}\n"
        );
        let lines = scan(src);
        let f = rule_config_drift("c.rs", &lines, "has `alpha` only", "has `alpha` and `beta`");
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].msg.contains("`beta`"));
        assert!(f[0].msg.contains("README.md"));
    }

    // -- doc-link -----------------------------------------------------------

    fn fixture_text(name: &str) -> String {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read fixture {}: {e}", path.display()))
    }

    #[test]
    fn doc_links_fire_on_fixture() {
        let text = fixture_text("doclink_bad.md");
        let exists =
            |p: &str| matches!(p, "README.md" | "docs/ARCHITECTURE.md");
        let f = rule_doc_links("docs/fixture.md", &text, &exists);
        let marked: Vec<usize> = text
            .lines()
            .enumerate()
            .filter(|(_, l)| l.contains("<!-- MARK -->"))
            .map(|(i, _)| i + 1)
            .collect();
        let found: Vec<usize> = f.iter().map(|x| x.line).collect();
        assert_eq!(found, marked, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "doc-link"));
    }

    #[test]
    fn doc_relative_and_root_relative_resolutions_both_count() {
        let exists = |p: &str| p == "docs/GUIDE.md";
        // from the repo root, plain form
        assert!(rule_doc_links("README.md", "[g](docs/GUIDE.md)", &exists).is_empty());
        // from inside docs/, doc-relative form
        assert!(rule_doc_links("docs/OTHER.md", "[g](GUIDE.md)", &exists).is_empty());
        // from inside docs/, root-relative form (the fallback resolution)
        assert!(rule_doc_links("docs/OTHER.md", "[g](docs/GUIDE.md)", &exists).is_empty());
        // a genuinely missing target fails from anywhere
        assert_eq!(rule_doc_links("docs/OTHER.md", "[g](NOPE.md)", &exists).len(), 1);
    }

    #[test]
    fn resolve_relative_normalises_and_bounds() {
        assert_eq!(resolve_relative("docs", "../README.md"), Some("README.md".into()));
        assert_eq!(resolve_relative("", "docs/./X.md"), Some("docs/X.md".into()));
        assert_eq!(resolve_relative("docs", "../../outside.md"), None);
    }

    #[test]
    fn bare_mentions_respect_token_boundaries() {
        assert_eq!(bare_doc_mentions("see docs/A.md and `docs/B.md`."), ["docs/A.md", "docs/B.md"]);
        // part of a longer path: the inline extractor's job, not this one
        assert!(bare_doc_mentions("at rust/docs/C.md").is_empty());
        assert!(bare_doc_mentions("the docs/ directory").is_empty());
    }

    #[test]
    fn repo_docs_have_no_broken_links() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
        let exists = |p: &str| root.join(p).exists();
        for doc in ["README.md", "docs/ARCHITECTURE.md", "docs/QUERY_PATH.md"] {
            let text = manifest_relative(&format!("../../{doc}"));
            let f = rule_doc_links(doc, &text, &exists);
            assert!(f.is_empty(), "{doc}: {f:?}");
        }
    }

    #[test]
    fn repo_config_keys_are_documented() {
        let config = scan(&manifest_relative("../src/coordinator/config.rs"));
        let readme = manifest_relative("../../README.md");
        let arch = manifest_relative("../../docs/ARCHITECTURE.md");
        let f = rule_config_drift("rust/src/coordinator/config.rs", &config, &readme, &arch);
        assert!(f.is_empty(), "{f:?}");
        // Sanity: the extractor sees the full key set, not a subset.
        assert!(extract_config_keys(&config).len() >= 25);
    }
}
