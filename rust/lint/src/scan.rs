//! A minimal comment/string-aware scanner over Rust source text.
//!
//! The linter does not parse Rust; it tokenises just enough to know, for
//! every character, whether it sits in code, in a comment, or inside a
//! string/char literal. Each source line is split into three *aligned*
//! views (same length, same columns) so the rules can do plain substring
//! searches without ever matching text inside a comment or a literal:
//!
//! * [`LineView::code`] — comments and string *contents* blanked to
//!   spaces (the quotes themselves are kept). `.unwrap()` inside a log
//!   message cannot fire the no-panic rule here.
//! * [`LineView::stripped`] — comments blanked, string contents kept.
//!   Used where the interesting token *is* a string literal, e.g. the
//!   config keys in `json.get("dim")`.
//! * [`LineView::comment`] — comment text only. `SAFETY:` and
//!   `LINT-ALLOW(...)` annotations are looked up here, so a string
//!   containing the word `SAFETY:` can never satisfy the unsafe audit.
//!
//! Handled syntax: line comments, nested block comments, string
//! literals with escapes, raw strings (`r"…"`, `r#"…"#`, `br"…"`), byte
//! strings, char literals, and the char-literal/lifetime ambiguity
//! (`'a'` vs `'a`). That is the full set of Rust constructs that can
//! embed a quote or a `//` and fool a naive grep.

/// One source line split into three aligned views; see the module docs.
#[derive(Debug, Clone)]
pub struct LineView {
    /// Comments and string contents blanked; quotes kept.
    pub code: String,
    /// Comments blanked; string contents kept.
    pub stripped: String,
    /// Comment text only; everything else blanked.
    pub comment: String,
}

/// Scanner state carried across lines (block comments and multi-line
/// strings continue onto the next line).
#[derive(Clone, Copy)]
enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

/// Accumulates the three per-line buffers and finished lines.
struct Builder {
    code: String,
    stripped: String,
    comment: String,
    lines: Vec<LineView>,
}

impl Builder {
    fn new() -> Self {
        Builder {
            code: String::new(),
            stripped: String::new(),
            comment: String::new(),
            lines: Vec::new(),
        }
    }

    /// Emit one character into the three views (aligned columns).
    fn put(&mut self, code: char, stripped: char, comment: char) {
        self.code.push(code);
        self.stripped.push(stripped);
        self.comment.push(comment);
    }

    /// A character that is plain code: visible in `code` and `stripped`.
    fn put_code(&mut self, c: char) {
        self.put(c, c, ' ');
    }

    /// A character inside a comment: visible only in `comment`.
    fn put_comment(&mut self, c: char) {
        self.put(' ', ' ', c);
    }

    /// String *content*: blanked in `code`, kept in `stripped`.
    fn put_str_content(&mut self, c: char) {
        self.put(' ', c, ' ');
    }

    fn end_line(&mut self) {
        self.lines.push(LineView {
            code: std::mem::take(&mut self.code),
            stripped: std::mem::take(&mut self.stripped),
            comment: std::mem::take(&mut self.comment),
        });
    }

    fn finish(mut self) -> Vec<LineView> {
        if !self.code.is_empty() || !self.comment.is_empty() {
            self.end_line();
        }
        self.lines
    }
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Try to recognise a raw-string opener (`r"`, `r#"`, `br##"` …) at
/// position `i`. Returns `(hash_count, index_past_opening_quote)`.
fn raw_string_open(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Split `src` into per-line code/stripped/comment views.
pub fn scan(src: &str) -> Vec<LineView> {
    let chars: Vec<char> = src.chars().collect();
    let mut b = Builder::new();
    let mut state = State::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if let State::LineComment = state {
                state = State::Code;
            }
            b.end_line();
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    b.put_comment('/');
                    b.put_comment('/');
                    i += 2;
                    state = State::LineComment;
                } else if c == '/' && next == Some('*') {
                    b.put_comment('/');
                    b.put_comment('*');
                    i += 2;
                    state = State::BlockComment(1);
                } else if (c == 'r' || c == 'b')
                    && (i == 0 || !is_ident(chars[i - 1]))
                    && raw_string_open(&chars, i).is_some()
                {
                    // Prefix (`r`, `b`, hashes) and the opening quote are
                    // code tokens; contents follow in RawStr state.
                    if let Some((hashes, past_quote)) = raw_string_open(&chars, i) {
                        for k in i..past_quote {
                            b.put_code(chars[k]);
                        }
                        i = past_quote;
                        state = State::RawStr(hashes);
                    }
                } else if c == '"' {
                    b.put_code('"');
                    i += 1;
                    state = State::Str;
                } else if c == '\'' {
                    if next == Some('\\') {
                        // Escaped char literal: consume until the
                        // closing quote.
                        b.put_code('\'');
                        i += 1;
                        while i < chars.len() && chars[i] != '\'' {
                            if chars[i] == '\\' && i + 1 < chars.len() {
                                b.put_str_content(chars[i]);
                                b.put_str_content(chars[i + 1]);
                                i += 2;
                            } else {
                                b.put_str_content(chars[i]);
                                i += 1;
                            }
                        }
                        if i < chars.len() {
                            b.put_code('\'');
                            i += 1;
                        }
                    } else if chars.get(i + 2) == Some(&'\'') && next != Some('\'') {
                        // Simple char literal: 'x'.
                        b.put_code('\'');
                        b.put_str_content(chars[i + 1]);
                        b.put_code('\'');
                        i += 3;
                    } else {
                        // A lifetime tick ('a, '_, 'static).
                        b.put_code('\'');
                        i += 1;
                    }
                } else {
                    b.put_code(c);
                    i += 1;
                }
            }
            State::LineComment => {
                b.put_comment(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    b.put_comment('/');
                    b.put_comment('*');
                    i += 2;
                    state = State::BlockComment(depth + 1);
                } else if c == '*' && next == Some('/') {
                    b.put_comment('*');
                    b.put_comment('/');
                    i += 2;
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                } else {
                    b.put_comment(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' && i + 1 < chars.len() {
                    b.put_str_content(c);
                    if chars[i + 1] != '\n' {
                        b.put_str_content(chars[i + 1]);
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    b.put_code('"');
                    i += 1;
                    state = State::Code;
                } else {
                    b.put_str_content(c);
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let closes = (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'));
                    if closes {
                        b.put_code('"');
                        for _ in 0..hashes {
                            b.put_code('#');
                        }
                        i += 1 + hashes as usize;
                        state = State::Code;
                    } else {
                        b.put_str_content(c);
                        i += 1;
                    }
                } else {
                    b.put_str_content(c);
                    i += 1;
                }
            }
        }
    }
    b.finish()
}

/// True when `needle` occurs in `haystack` with non-identifier characters
/// (or the line boundary) on both sides.
pub fn contains_word(haystack: &str, needle: &str) -> bool {
    let hay: Vec<char> = haystack.chars().collect();
    let ned: Vec<char> = needle.chars().collect();
    if ned.is_empty() || hay.len() < ned.len() {
        return false;
    }
    for start in 0..=hay.len() - ned.len() {
        if hay[start..start + ned.len()] != ned[..] {
            continue;
        }
        let before_ok = start == 0 || !is_ident(hay[start - 1]);
        let after = start + ned.len();
        let after_ok = after == hay.len() || !is_ident(hay[after]);
        if before_ok && after_ok {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(src: &str) -> Vec<LineView> {
        scan(src)
    }

    #[test]
    fn line_comment_is_blanked_from_code() {
        let v = views("let x = 1; // unsafe unwrap()\n");
        assert!(!v[0].code.contains("unsafe"));
        assert!(v[0].code.contains("let x = 1;"));
        assert!(v[0].comment.contains("unsafe unwrap()"));
    }

    #[test]
    fn string_contents_blanked_in_code_kept_in_stripped() {
        let v = views("let s = \"call .unwrap() now\";\n");
        assert!(!v[0].code.contains(".unwrap()"));
        assert!(v[0].stripped.contains(".unwrap()"));
        // The quotes themselves stay visible in the code view.
        assert_eq!(v[0].code.matches('"').count(), 2);
    }

    #[test]
    fn nested_block_comment_spans_lines() {
        let v = views("/* outer /* inner */ still comment */ let y = 2;\nlet z = 3;\n");
        assert!(v[0].code.contains("let y = 2;"));
        assert!(!v[0].code.contains("inner"));
        assert!(v[0].comment.contains("inner"));
        assert!(v[1].code.contains("let z = 3;"));
    }

    #[test]
    fn raw_string_with_hashes() {
        let v = views("let r = r#\"has \"quotes\" and // no comment\"#; // real\n");
        assert!(!v[0].code.contains("quotes"));
        assert!(v[0].stripped.contains("has \"quotes\""));
        assert!(v[0].comment.contains("real"));
        assert!(!v[0].comment.contains("no comment"));
    }

    #[test]
    fn char_literal_quote_does_not_open_string() {
        let v = views("let c = '\"'; let d = 1; // tail\n");
        assert!(v[0].code.contains("let d = 1;"));
        assert!(v[0].comment.contains("tail"));
    }

    #[test]
    fn lifetime_tick_is_not_a_char_literal() {
        let v = views("fn f<'a>(x: &'a str) -> &'a str { x } // ok\n");
        assert!(v[0].code.contains("fn f<"));
        assert!(v[0].comment.contains("ok"));
    }

    #[test]
    fn escaped_char_literal() {
        let v = views("let n = '\\n'; let q = '\\''; // c\n");
        assert!(v[0].comment.contains('c'));
        assert!(v[0].code.contains("let q ="));
    }

    #[test]
    fn multi_line_string_continues() {
        let v = views("let s = \"first\nsecond // not a comment\";\nlet t = 4;\n");
        assert!(!v[1].code.contains("second"));
        assert!(v[1].comment.trim().is_empty());
        assert!(v[2].code.contains("let t = 4;"));
    }

    #[test]
    fn views_stay_column_aligned() {
        for line in views("let s = \"x\"; // c\nunsafe { /* b */ }\n") {
            assert_eq!(line.code.chars().count(), line.stripped.chars().count());
            assert_eq!(line.code.chars().count(), line.comment.chars().count());
        }
    }

    #[test]
    fn word_boundaries() {
        assert!(contains_word("unsafe { }", "unsafe"));
        assert!(contains_word("(unsafe)", "unsafe"));
        assert!(!contains_word("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(!contains_word("not_unsafe", "unsafe"));
    }
}
