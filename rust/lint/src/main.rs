//! `lmds-lint` — the in-tree invariant linter for the lmds-ose workspace.
//!
//! Run locally with `cargo run -p lmds-lint` (from anywhere inside the
//! repo); CI runs it as the blocking `lint-invariants` job. It scans the
//! `.rs` tree with a comment/string-aware token scanner ([`scan`]) and
//! enforces six project invariants the compiler can't ([`rules`]):
//! unsafe-audit, no-panic serving paths, wire-stability, config/docs
//! drift, doc-link integrity of the user-facing markdown, and style
//! bans. Exit status 0 means clean; 1 means findings
//! (printed as `path:line: [rule] message`) or an I/O / setup error.
//!
//! See docs/ARCHITECTURE.md, "Static analysis & sanitizers", for the
//! rule table, override syntax, and the add-a-rule checklist.

mod rules;
mod scan;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use rules::{Allowlist, Finding};
use scan::LineView;

/// Directories scanned for `.rs` files (repo-relative). `fixtures/`
/// subtrees are excluded — they hold known-bad lint test inputs.
const SCAN_ROOTS: &[&str] = &[
    "rust/src",
    "rust/lint/src",
    "rust/xla-stub/src",
    "rust/tests",
    "rust/benches",
    "examples",
];

const ALLOWLIST_PATH: &str = "rust/lint/lint-allow.txt";
const GOLDEN_PATH: &str = "rust/lint/golden/wire_abi.txt";
const ERROR_RS: &str = "rust/src/coordinator/error.rs";
const PROTO_RS: &str = "rust/src/coordinator/proto.rs";
const CONFIG_RS: &str = "rust/src/coordinator/config.rs";

fn main() -> ExitCode {
    match run() {
        Ok((scanned, findings)) if findings.is_empty() => {
            println!("lmds-lint: {scanned} files scanned, clean");
            ExitCode::SUCCESS
        }
        Ok((scanned, findings)) => {
            for f in &findings {
                println!("{f}");
            }
            println!("lmds-lint: {scanned} files scanned, {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("lmds-lint: error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<(usize, Vec<Finding>), String> {
    let root = find_root()?;
    let allow_text = read_rel(&root, ALLOWLIST_PATH)?;
    let allow = Allowlist::parse(&allow_text)?;

    let mut files: Vec<PathBuf> = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    files.sort();

    let mut findings: Vec<Finding> = Vec::new();
    let mut views: BTreeMap<String, Vec<LineView>> = BTreeMap::new();
    for path in &files {
        let rel = rel_path(&root, path);
        let src = fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let lines = scan::scan(&src);
        findings.extend(rules::rule_unsafe_audit(&rel, &lines, &allow));
        findings.extend(rules::rule_no_panic(&rel, &lines));
        findings.extend(rules::rule_style(&rel, &lines));
        views.insert(rel, lines);
    }

    let golden = read_rel(&root, GOLDEN_PATH)?;
    match (views.get(ERROR_RS), views.get(PROTO_RS)) {
        (Some(error_lines), Some(proto_lines)) => {
            findings.extend(rules::rule_wire_stability(
                ERROR_RS,
                error_lines,
                PROTO_RS,
                proto_lines,
                &golden,
                GOLDEN_PATH,
            ));
        }
        _ => return Err(format!("{ERROR_RS} / {PROTO_RS} not found in the scanned tree")),
    }

    let readme = read_rel(&root, "README.md")?;
    let arch = read_rel(&root, "docs/ARCHITECTURE.md")?;
    match views.get(CONFIG_RS) {
        Some(config_lines) => {
            findings.extend(rules::rule_config_drift(CONFIG_RS, config_lines, &readme, &arch));
        }
        None => return Err(format!("{CONFIG_RS} not found in the scanned tree")),
    }

    // doc-link: the user-facing markdown set must not reference paths
    // that do not exist in the tree
    let query_path = read_rel(&root, "docs/QUERY_PATH.md")?;
    let exists = |p: &str| root.join(p).exists();
    for (doc, text) in [
        ("README.md", &readme),
        ("docs/ARCHITECTURE.md", &arch),
        ("docs/QUERY_PATH.md", &query_path),
    ] {
        findings.extend(rules::rule_doc_links(doc, text, &exists));
    }

    findings.sort_by(|a, b| a.path.cmp(&b.path).then(a.line.cmp(&b.line)));
    Ok((files.len(), findings))
}

/// Repo root: `$LMDS_LINT_ROOT` if set, else the nearest ancestor of the
/// working directory containing `rust/src/lib.rs`.
fn find_root() -> Result<PathBuf, String> {
    if let Ok(root) = std::env::var("LMDS_LINT_ROOT") {
        return Ok(PathBuf::from(root));
    }
    let mut dir = std::env::current_dir().map_err(|e| format!("current_dir: {e}"))?;
    loop {
        if dir.join("rust/src/lib.rs").is_file() {
            return Ok(dir);
        }
        if !dir.pop() {
            return Err("repo root not found (no rust/src/lib.rs in any ancestor of the \
                        working directory); set LMDS_LINT_ROOT"
                .to_string());
        }
    }
}

fn read_rel(root: &Path, rel: &str) -> Result<String, String> {
    let path = root.join(rel);
    fs::read_to_string(&path).map_err(|e| format!("read {rel}: {e}"))
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root).unwrap_or(path).display().to_string()
}

/// Recursively collect `.rs` files, skipping `fixtures/` directories.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "fixtures") {
                continue;
            }
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
