//! Known-bad fixture for the no-panic rule (the tests pass it under a
//! serving-path name). Marker comments tag the expected findings.
//! Never compiled — read as text by the tests in `src/rules.rs`.

pub fn parse(v: Option<u8>) -> u8 {
    v.unwrap() // MARK
}

pub fn header(buf: &[u8]) -> u8 {
    let b = buf.first().expect("empty buffer"); // MARK
    *b
}

pub fn fail() -> u8 {
    panic!("boom") // MARK
}

pub fn later() {
    todo!() // MARK
}

pub fn startup(v: Option<u8>) -> u8 {
    // LINT-ALLOW(panic): construction-time invariant, not a request path.
    v.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_inside_cfg_test_are_fine() {
        Some(1u8).unwrap();
    }
}
