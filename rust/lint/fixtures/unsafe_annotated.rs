//! Known-good fixture: every `unsafe` site carries an annotation the
//! unsafe-audit rule accepts (same-line comment, preceding comment,
//! comment above attributes, or a `# Safety` doc section).
//! Never compiled — read as text by the tests in `src/rules.rs`.

fn read_first(bytes: &[u8]) -> u8 {
    let p = bytes.as_ptr();
    // SAFETY: `bytes` is non-empty at every call site in this fixture.
    unsafe { *p }
}

struct Wrapper(*mut u8);

// SAFETY: the pointer is only dereferenced behind a lock.
unsafe impl Send for Wrapper {}

/// Reads a byte without bounds checking.
///
/// # Safety
/// `i` must be in bounds for `bytes`.
#[inline]
pub unsafe fn get_unchecked(bytes: &[u8], i: usize) -> u8 {
    // SAFETY: the caller upholds the `# Safety` contract above.
    unsafe { *bytes.as_ptr().add(i) }
}

fn tail() -> u8 {
    let arr = [1u8, 2];
    unsafe { get_unchecked(&arr, 0) } // SAFETY: index 0 is in bounds.
}
