//! Known-bad fixture: `unsafe` sites with no SAFETY annotation.
//! Marker comments tag the lines the unsafe-audit rule must report.
//! Never compiled — read as text by the tests in `src/rules.rs`.

fn read_first(bytes: &[u8]) -> u8 {
    let p = bytes.as_ptr();
    unsafe { *p } // MARK
}

struct Wrapper(*mut u8);

// A comment that is not a safety argument does not count.
unsafe impl Send for Wrapper {} // MARK
