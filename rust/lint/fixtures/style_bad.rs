//! Known-bad fixture for the style rule. Marker comments tag the
//! lines the rule must report.
//! Never compiled — read as text by the tests in `src/rules.rs`.

pub fn run() -> Result<(), String> { // MARK
    Err("stringly typed".to_string())
}

fn bail() {
    std::process::exit(3); // MARK
}

pub fn typed() -> Result<(), std::io::Error> {
    Ok(())
}

pub fn ok_string_payload() -> Result<String, std::io::Error> {
    // String as the Ok type is fine; only stringly-typed errors are banned.
    Ok(String::new())
}

// LINT-ALLOW(style): exercised by the fixture tests.
pub fn allowed() -> Result<(), String> {
    Ok(())
}
