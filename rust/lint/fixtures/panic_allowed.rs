//! Known-good fixture: serving-path code that propagates typed errors
//! instead of panicking. The no-panic rule must stay silent here.
//! Never compiled — read as text by the tests in `src/rules.rs`.

pub enum ServeError {
    BadInput,
}

pub fn parse(v: Option<u8>) -> Result<u8, ServeError> {
    v.ok_or(ServeError::BadInput)
}

pub fn header(buf: &[u8]) -> Result<u8, ServeError> {
    match buf.first() {
        Some(b) => Ok(*b),
        None => Err(ServeError::BadInput),
    }
}

pub fn fallback(v: Option<u8>) -> u8 {
    // Non-panicking relatives are fine: unwrap_or, unwrap_or_default…
    v.unwrap_or(0)
}

pub fn log_line() -> &'static str {
    // Banned tokens inside string literals are not code.
    "refusing to .unwrap() or panic! on the serving path"
}
