//! Build-time stand-in for the real XLA/PJRT bindings.
//!
//! The `pjrt` feature of `lmds-ose` compiles its artifact-execution path
//! against this API surface (the subset of xla-rs the runtime uses) so the
//! feature builds on any machine — CI included — without the multi-GB
//! `xla_extension` toolchain. Every constructor returns an error at
//! runtime, which the runtime layer reports once and then falls back to
//! the pure-Rust native backend.
//!
//! To run real artifacts, replace the `xla` path dependency in
//! `rust/Cargo.toml` with actual bindings exposing this same surface:
//!
//! ```toml
//! xla = { path = "/path/to/xla-rs", optional = true }
//! ```

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type: everything in the stub fails with this.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} needs real XLA bindings (this build links the \
         in-tree xla-stub; see README.md \"Compute backends\")"
    )))
}

/// PJRT client handle (unconstructible in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

pub struct PjRtDevice {
    _private: (),
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_with_stub_message() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
    }
}
