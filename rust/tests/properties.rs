//! Cross-module property tests (the S6 mini-framework): invariants that
//! span the dissimilarity engine, the MDS metrics, the OSE methods, the
//! Geco generator and the serving path.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use lmds_ose::coordinator::embedder::solve_base;
use lmds_ose::coordinator::{
    BackendOpt, BaseSolver, BatcherConfig, Request, ServerBuilder,
};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::{cross_matrix, full_matrix};
use lmds_ose::mds::stress::{point_error, raw_stress, total_error};
use lmds_ose::mds::{LsmdsConfig, Matrix};
use lmds_ose::nn::{MlpParams, MlpShape};
use lmds_ose::ose::{
    embed_point, embed_stream_blocks, factory_fn, OseOptConfig, RustNn,
};
use lmds_ose::runtime::simd::set_kernel_tier;
use lmds_ose::runtime::{Backend, KernelTier};
use lmds_ose::strdist::{
    euclidean, levenshtein, DamerauOsa, Dissimilarity, JaroWinkler, Levenshtein, QGram,
    SoundexDist,
};
use lmds_ose::util::json::Json;
use lmds_ose::util::prng::Rng;
use lmds_ose::util::quickcheck::{prop_assert, prop_assert_close, property, Gen};

fn random_config(g: &mut Gen, n: usize, k: usize) -> Matrix {
    Matrix::from_vec(n, k, (0..n * k).map(|_| g.f32_in(-3.0, 3.0)).collect())
}

fn distances_of(x: &Matrix) -> Matrix {
    let n = x.rows;
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            d.set(i, j, euclidean(x.row(i), x.row(j)) as f32);
        }
    }
    d
}

#[test]
fn stress_zero_iff_realizable() {
    property("stress == 0 iff delta realizable", 60, |g| {
        let n = g.usize_in(3, 12);
        let k = g.usize_in(1, 4);
        let x = random_config(g, n, k);
        let delta = distances_of(&x);
        prop_assert(raw_stress(&x, &delta) < 1e-6, "realizable => zero stress")?;
        // perturb one dissimilarity: stress must become positive
        let mut bad = delta.clone();
        let (i, j) = (0, n - 1);
        if i != j {
            bad.set(i, j, bad.at(i, j) + 1.0);
            bad.set(j, i, bad.at(j, i) + 1.0);
            prop_assert(raw_stress(&x, &bad) > 0.5, "perturbed => positive stress")?;
        }
        Ok(())
    });
}

#[test]
fn total_error_decomposes_and_scales() {
    property("Err(m) sums weighted point residuals", 40, |g| {
        let n = g.usize_in(3, 10);
        let m = g.usize_in(1, 5);
        let k = g.usize_in(1, 3);
        let config = random_config(g, n, k);
        let y = random_config(g, m, k);
        let delta = Matrix::from_vec(
            m,
            n,
            (0..m * n).map(|_| g.f32_in(0.1, 5.0)).collect(),
        );
        let total = total_error(&config, &delta, &y);
        prop_assert(total >= 0.0 && total.is_finite(), "non-negative finite")?;
        // manual recomputation
        let mut want = 0.0f64;
        for j in 0..m {
            for i in 0..n {
                let d = euclidean(config.row(i), y.row(j));
                let dl = delta.at(j, i) as f64;
                want += (dl - d).powi(2) / dl;
            }
        }
        prop_assert_close(total, want, 1e-6 * (1.0 + want), "decomposition")
    });
}

#[test]
fn ose_optimisation_never_worsens_objective() {
    property("majorization monotone from any start", 40, |g| {
        let l = g.usize_in(3, 30);
        let k = g.usize_in(1, 5);
        let lm = random_config(g, l, k);
        let delta: Vec<f32> = (0..l).map(|_| g.f32_in(0.1, 6.0)).collect();
        let y0: Vec<f32> = (0..k).map(|_| g.f32_in(-2.0, 2.0)).collect();
        let p1 = embed_point(&lm, &delta, Some(&y0), &OseOptConfig {
            max_iters: 1,
            rel_tol: 0.0,
        });
        let p50 = embed_point(&lm, &delta, Some(&y0), &OseOptConfig {
            max_iters: 50,
            rel_tol: 0.0,
        });
        prop_assert(
            p50.objective <= p1.objective + 1e-6 * (1.0 + p1.objective),
            &format!("{} -> {}", p1.objective, p50.objective),
        )
    });
}

#[test]
fn ose_point_error_bounded_by_objective_triangle() {
    // PErr against the landmarks only (delta restricted) equals the Eq.-2
    // objective at the final iterate
    property("PErr over landmarks == objective", 40, |g| {
        let l = g.usize_in(3, 20);
        let k = g.usize_in(1, 4);
        let lm = random_config(g, l, k);
        let delta: Vec<f32> = (0..l).map(|_| g.f32_in(0.1, 6.0)).collect();
        let p = embed_point(&lm, &delta, None, &OseOptConfig::default());
        let perr = point_error(&lm, &delta, &p.coords);
        prop_assert_close(perr, p.objective, 1e-4 * (1.0 + perr), "identity")
    });
}

// ---------------------------------------------------------------------------
// Per-metric axiom suites: every strdist comparator must satisfy identity
// (d(a, a) = 0), symmetry and non-negativity over ASCII and unicode-ish
// inputs; the triangle inequality is asserted only for the metrics that
// actually satisfy it, with documented exemptions for the rest.
// ---------------------------------------------------------------------------

fn metric_axiom_suite(metric: &dyn Dissimilarity<str>) {
    property(
        &format!("{}: identity + symmetry + non-negativity", metric.name()),
        150,
        |g| {
            let (a, b) = if g.bool() {
                (g.string(0, 14), g.string(0, 14))
            } else {
                (g.unicode_string(0, 10), g.unicode_string(0, 10))
            };
            prop_assert(metric.dist(&a, &a) == 0.0, &format!("identity on {a:?}"))?;
            prop_assert(metric.dist(&b, &b) == 0.0, &format!("identity on {b:?}"))?;
            let ab = metric.dist(&a, &b);
            let ba = metric.dist(&b, &a);
            prop_assert(ab == ba, &format!("symmetry {a:?}/{b:?}: {ab} vs {ba}"))?;
            prop_assert(ab >= 0.0 && ab.is_finite(), "non-negative and finite")
        },
    );
}

fn metric_triangle_suite(metric: &dyn Dissimilarity<str>) {
    property(&format!("{}: triangle inequality", metric.name()), 200, |g| {
        let a = g.string(0, 10);
        let b = g.string(0, 10);
        let c = g.string(0, 10);
        let ab = metric.dist(&a, &b);
        let ac = metric.dist(&a, &c);
        let cb = metric.dist(&c, &b);
        prop_assert(
            ab <= ac + cb + 1e-9,
            &format!("d({a:?},{b:?})={ab} > {ac} + {cb} (via {c:?})"),
        )
    });
}

#[test]
fn strdist_axioms_levenshtein() {
    metric_axiom_suite(&Levenshtein);
    metric_triangle_suite(&Levenshtein); // a true metric
}

#[test]
fn strdist_axioms_damerau_osa() {
    metric_axiom_suite(&DamerauOsa);
    // Triangle exemption: OSA (the *restricted* Damerau variant, matching
    // stringdist's "osa") is NOT a metric. Canonical counterexample:
    // d("ca","abc") = 3, but d("ca","ac") + d("ac","abc") = 1 + 1 = 2.
    // (The unrestricted Damerau-Levenshtein distance would be a metric.)
    let d = |a: &str, b: &str| DamerauOsa.dist(a, b);
    assert!(
        d("ca", "abc") > d("ca", "ac") + d("ac", "abc"),
        "OSA triangle counterexample no longer violates — metric changed?"
    );
}

#[test]
fn strdist_axioms_jaro_winkler() {
    metric_axiom_suite(&JaroWinkler);
    // Triangle exemption: Jaro(-Winkler) is a similarity-derived
    // dissimilarity, not a metric — totally dissimilar strings saturate at
    // distance 1.0, so two "hops" through an unrelated middle string can
    // be cheaper than the direct comparison's structure allows, e.g.
    // d("ab","ba") vs hops through "" are incomparable under the matching
    // window. We pin one concrete violation so the exemption stays honest.
    let d = |a: &str, b: &str| JaroWinkler.dist(a, b);
    // "abcde" vs "edcba": low direct similarity; via "abcba" both hops are
    // close, giving a strictly cheaper path
    let direct = d("abcde", "edcba");
    let via = d("abcde", "abcba") + d("abcba", "edcba");
    assert!(
        direct > via,
        "expected JW triangle violation: direct {direct} vs via {via}"
    );
}

#[test]
fn strdist_axioms_qgram() {
    for q in [2usize, 3] {
        metric_axiom_suite(&QGram(q));
        // q-gram distance is the L1 distance between q-gram profiles: a
        // pseudometric on strings (identity of indiscernibles fails —
        // strings shorter than q share the empty profile — but the
        // triangle inequality holds)
        metric_triangle_suite(&QGram(q));
    }
}

#[test]
fn strdist_axioms_soundex() {
    metric_axiom_suite(&SoundexDist);
    // soundex_distance = levenshtein over 4-char codes: the pullback of a
    // metric along the encoding, hence a pseudometric — triangle holds
    metric_triangle_suite(&SoundexDist);
}

#[test]
fn euclidean_vector_metric_axioms() {
    property("euclidean: axioms + triangle on vectors", 120, |g| {
        let k = g.usize_in(1, 6);
        let a: Vec<f32> = (0..k).map(|_| g.f32_in(-5.0, 5.0)).collect();
        let b: Vec<f32> = (0..k).map(|_| g.f32_in(-5.0, 5.0)).collect();
        let c: Vec<f32> = (0..k).map(|_| g.f32_in(-5.0, 5.0)).collect();
        prop_assert(euclidean(&a, &a) == 0.0, "identity")?;
        let ab = euclidean(&a, &b);
        prop_assert(ab == euclidean(&b, &a), "symmetry")?;
        prop_assert(ab >= 0.0 && ab.is_finite(), "non-negative")?;
        prop_assert(
            ab <= euclidean(&a, &c) + euclidean(&c, &b) + 1e-9,
            "triangle",
        )
    });
}

#[test]
fn dissimilarity_matrices_consistent() {
    property("full vs cross vs scalar agree", 30, |g| {
        let n = g.usize_in(2, 12);
        let mut geco = Geco::new(GecoConfig { seed: g.u64(), ..Default::default() });
        let names = geco.generate_unique(n);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let full = full_matrix(&objs, &Levenshtein);
        let cross = cross_matrix(&objs, &objs, &Levenshtein);
        for i in 0..n {
            for j in 0..n {
                let want = levenshtein(&names[i], &names[j]) as f32;
                prop_assert(full.at(i, j) == want, "full entry")?;
                prop_assert(cross.at(i, j) == want, "cross entry")?;
            }
        }
        Ok(())
    });
}

#[test]
fn geco_corruption_edit_distance_bounded() {
    property("k corruptions move <= 4k edits", 80, |g| {
        let seed = g.u64();
        let k = g.usize_in(1, 4);
        let mut geco = Geco::new(GecoConfig { seed, ..Default::default() });
        let name = geco.sample_name();
        let mut s = name.clone();
        for _ in 0..k {
            s = geco.corrupt(&s);
        }
        let d = levenshtein(&name, &s);
        prop_assert(d <= 4 * k, &format!("{name:?} -> {s:?}: d={d} k={k}"))
    });
}

#[test]
fn json_round_trips_arbitrary_values() {
    property("json round-trip", 120, |g| {
        // build a random JSON value of bounded depth
        fn build(g: &mut Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                3 => Json::Str(g.unicode_string(0, 12)),
                4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| build(g, depth - 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for _ in 0..g.usize_in(0, 4) {
                        m.insert(g.string(0, 8), build(g, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = build(g, 3);
        let compact = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
        prop_assert(compact == v, "compact round-trip")?;
        prop_assert(pretty == v, "pretty round-trip")
    });
}

#[test]
fn server_never_drops_or_duplicates() {
    // fire N concurrent queries; exactly N distinct replies, none lost
    let mut rng = Rng::new(99);
    let landmarks: Vec<String> = (0..16).map(|i| format!("lm{i}")).collect();
    let params = MlpParams::init(
        &MlpShape { input: 16, hidden: [8, 8, 8], output: 3 },
        &mut rng,
    );
    let server = ServerBuilder::strings(
        landmarks,
        Arc::new(Levenshtein),
        factory_fn(move || Box::new(RustNn { params: params.clone() })),
    )
    .batcher(BatcherConfig {
        max_batch: 7, // deliberately not a divisor of the load
        max_delay: Duration::from_millis(1),
        queue_cap: 32, // small: exercises backpressure
        frontend_threads: 3,
        replicas: 3, // replicated pool must preserve exactly-once too
    })
    .build()
    .expect("valid server configuration");
    let sh = server.handle();
    let n = 500;
    let tickets: Vec<_> = (0..n)
        .map(|i| sh.submit(Request::object(format!("query {i}"))))
        .collect();
    let mut ok = 0;
    for t in tickets {
        // every ticket yields exactly one result
        t.recv().expect("reply must arrive");
        ok += 1;
        assert!(t.try_recv().is_none(), "duplicate reply");
    }
    assert_eq!(ok, n);
    let snap = sh.metrics.snapshot();
    assert_eq!(snap.completed, n as u64);
    assert_eq!(snap.failed, 0);
    drop(sh);
    server.shutdown();
}

/// Serialises the tests in this file that flip the process-global kernel
/// tier, so a concurrently running test cannot observe a half-flipped
/// tier (which could mask a real divergence between the tiers).
static TIER_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn kernel_tier_dispatch_invariance_end_to_end() {
    // `--kernel-tier simd` and `--kernel-tier scalar` must produce
    // bit-identical results end-to-end: the vector kernels preserve the
    // scalar tier's canonical reduction order (see runtime::simd), so this
    // is exact `Vec<f32>` equality, not a tolerance band. On hosts without
    // the vector ISA the simd request resolves to scalar and the assertion
    // is trivially true — the x86_64 CI runners exercise the real case.
    let _guard = TIER_LOCK.lock().unwrap();
    let mut rng = Rng::new(41);
    let hidden = Matrix::random_normal(&mut rng, 40, 3, 1.0);
    let delta = distances_of(&hidden);
    let queries = Matrix::random_normal(&mut rng, 24, 3, 1.2);
    let cfg = LsmdsConfig {
        dim: 3,
        max_iters: 20,
        rel_tol: 0.0,
        seed: 11,
        ..Default::default()
    };

    let mut configs: Vec<Matrix> = Vec::new();
    let mut streams: Vec<Matrix> = Vec::new();
    for tier in [KernelTier::Scalar, KernelTier::Simd] {
        set_kernel_tier(tier);
        let backend = Backend::native();

        // Stage 1: the monolithic base solve (stress_gradient_blocked).
        let (config, sigma) =
            solve_base(&delta, &cfg, BaseSolver::Monolithic, &backend)
                .expect("base solve succeeds on both tiers");
        assert!(sigma.is_finite());

        // Stage 2: the streamed OSE pipeline over the solved landmarks.
        let mut qd = Matrix::zeros(queries.rows, hidden.rows);
        for q in 0..queries.rows {
            for i in 0..hidden.rows {
                qd.set(q, i, euclidean(queries.row(q), hidden.row(i)) as f32);
            }
        }
        let mut method = BackendOpt {
            total_steps: 12,
            rel_tol: 0.0,
            ..BackendOpt::with_defaults(backend, config.clone())
        };
        let mut out = Matrix::zeros(queries.rows, cfg.dim);
        embed_stream_blocks(
            queries.rows,
            7, // deliberately not a divisor of the row count
            |start, end| {
                Matrix::from_vec(
                    end - start,
                    qd.cols,
                    qd.data[start * qd.cols..end * qd.cols].to_vec(),
                )
            },
            &mut method,
            |start, block| {
                for r in 0..block.rows {
                    out.row_mut(start + r).copy_from_slice(block.row(r));
                }
                Ok(())
            },
        )
        .expect("streamed embedding succeeds on both tiers");

        configs.push(config);
        streams.push(out);
    }
    set_kernel_tier(KernelTier::Auto);

    assert_eq!(
        configs[0].data, configs[1].data,
        "solve_base diverged between kernel tiers"
    );
    assert_eq!(
        streams[0].data, streams[1].data,
        "embed_stream_blocks diverged between kernel tiers"
    );
}

#[test]
fn nn_embedding_is_lipschitz_in_input() {
    // small input perturbations must not explode through the MLP (sanity
    // bound on the learned map's continuity; catches NaN/inf weight bugs)
    property("mlp forward is continuous", 40, |g| {
        let l = g.usize_in(4, 24);
        let mut rng = Rng::new(g.u64());
        let params = MlpParams::init(
            &MlpShape { input: l, hidden: [16, 16, 8], output: 3 },
            &mut rng,
        );
        let base: Vec<f32> = (0..l).map(|_| g.f32_in(0.0, 5.0)).collect();
        let mut pert = base.clone();
        let idx = g.usize_in(0, l - 1);
        pert[idx] += 0.01;
        let a = lmds_ose::nn::forward(&params, &Matrix::from_vec(1, l, base));
        let b = lmds_ose::nn::forward(&params, &Matrix::from_vec(1, l, pert));
        let diff = a.max_abs_diff(&b);
        prop_assert(diff.is_finite() && diff < 10.0, &format!("diff {diff}"))
    });
}
