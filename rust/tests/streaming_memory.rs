//! Bounded-memory guarantee of the streaming OSE pipeline, enforced by a
//! tracking global allocator: streaming N rows against L landmarks must
//! never allocate an `N x L` block anywhere on the path, and its peak
//! transient footprint must fit the `O(L² + 2·chunk·L)` budget (plus the
//! `N x K` output) — a budget a monolithic `N x L` dissimilarity matrix
//! alone could not fit in. This file holds exactly one test so the
//! allocator counters see no concurrent neighbours.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use lmds_ose::coordinator::methods::BackendOpt;
use lmds_ose::data::synthetic::gaussian_clusters;
use lmds_ose::mds::dissimilarity::cross_matrix;
use lmds_ose::mds::Matrix;
use lmds_ose::ose::pipeline::embed_stream;
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Euclidean;
use lmds_ose::util::prng::Rng;

/// Live bytes, high-water mark of live bytes, and largest single
/// allocation — updated on every alloc/dealloc in this test binary.
static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
static LARGEST: AtomicUsize = AtomicUsize::new(0);

struct TrackingAlloc;

impl TrackingAlloc {
    fn on_alloc(size: usize) {
        let live = LIVE.fetch_add(size, Ordering::Relaxed) + size;
        PEAK.fetch_max(live, Ordering::Relaxed);
        LARGEST.fetch_max(size, Ordering::Relaxed);
    }
}

unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            Self::on_alloc(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc_zeroed(layout) };
        if !p.is_null() {
            Self::on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) };
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
            Self::on_alloc(new_size);
        }
        p
    }
}

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

#[test]
fn streaming_embeds_within_transient_budget() {
    // Acceptance shapes: L = 300 landmarks; N = 100k synthetic points in
    // release (the CI `cargo test --release` job), scaled to 20k under the
    // debug tier-1 run so `cargo test -q` stays fast. The budget maths are
    // identical at both sizes.
    let n: usize = if cfg!(debug_assertions) { 20_000 } else { 100_000 };
    let l = 300usize;
    let k = 7usize;
    let chunk = 512usize;

    // -- setup (all of this is baseline memory, allocated before the run) --
    let mut rng = Rng::new(0xb0b);
    let points = gaussian_clusters(&mut rng, n, 3, 8, 1.0);
    let lm_points = gaussian_clusters(&mut rng, l, 3, 8, 1.0);
    let objs: Vec<&[f32]> = points.iter().map(|p| p.as_slice()).collect();
    let lm_refs: Vec<&[f32]> = lm_points.iter().map(|p| p.as_slice()).collect();
    let lm_config = Matrix::random_normal(&mut rng, l, k, 1.0);
    // tiny fixed step budget: the memory profile is what this test is
    // about, and rel_tol = 0 keeps the arithmetic chunk-invariant
    let mk_method = || {
        let mut m = BackendOpt::with_defaults(Backend::native(), lm_config.clone());
        m.total_steps = 2;
        m.rel_tol = 0.0;
        m
    };

    let monolithic_bytes = n * l * std::mem::size_of::<f32>();
    let budget_bytes = l * l * 4            // delta_LL the full pipeline holds
        + 2 * chunk * l * 4                 // the two in-flight stream blocks
        + n * k * 4                         // the N x K output
        + (8 << 20); // slack: thread-pool scratch, per-chunk coords, harness
    assert!(
        budget_bytes < monolithic_bytes,
        "the test budget ({budget_bytes} B) must be smaller than one \
         monolithic N x L matrix ({monolithic_bytes} B), or it proves nothing"
    );

    // -- measured region --
    let baseline = LIVE.load(Ordering::Relaxed);
    PEAK.store(baseline, Ordering::Relaxed);
    LARGEST.store(0, Ordering::Relaxed);

    let mut method = mk_method();
    let (coords, stats) =
        embed_stream(&objs, &lm_refs, &Euclidean, &mut method, chunk).unwrap();

    let peak_extra = PEAK.load(Ordering::Relaxed).saturating_sub(baseline);
    let largest = LARGEST.load(Ordering::Relaxed);
    // -- end measured region --

    assert_eq!((coords.rows, coords.cols), (n, k));
    assert!(coords.data.iter().all(|v| v.is_finite()));
    assert_eq!(stats.rows, n);
    assert_eq!(stats.chunks, n.div_ceil(chunk));
    assert!(stats.max_chunk_rows <= chunk);

    // no N x L allocation anywhere on the path
    assert!(
        largest < monolithic_bytes / 2,
        "largest single allocation {largest} B is within 2x of a \
         monolithic N x L matrix ({monolithic_bytes} B) — something \
         materialised the full out-of-sample block"
    );
    // and the whole transient footprint fits the streaming budget
    assert!(
        peak_extra < budget_bytes,
        "peak transient memory {peak_extra} B exceeds the \
         O(L^2 + 2*chunk*L) + output budget {budget_bytes} B"
    );

    // correctness spot-check: the first rows match the monolithic path
    // bit-for-bit
    let head: Vec<&[f32]> = objs[..5].to_vec();
    let delta_head = cross_matrix(&head, &lm_refs, &Euclidean);
    let mut mono_method = mk_method();
    let mono_head = mono_method.embed(&delta_head).unwrap();
    assert_eq!(&coords.data[..5 * k], &mono_head.data[..]);
}
