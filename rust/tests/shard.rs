//! Integration: sharded serving. Partition parity — the quorum-reduced
//! sharded embedding must land within the divide-solve partition-
//! invariance band of the unsharded optimisation OSE for S in {1, 2, 4} —
//! plus the chaos suite: killing a shard mid-soak must cost accuracy
//! (degraded flag), never availability, and losing the quorum must fail
//! queries with a typed error instead of hanging.

use std::time::Duration;

use std::sync::Arc;

use lmds_ose::coordinator::methods::BackendOpt;
use lmds_ose::coordinator::{
    BatcherConfig, Request, ServeError, Server, ServerBuilder, ShardConfig,
};
use lmds_ose::mds::Matrix;
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Euclidean;
use lmds_ose::util::prng::Rng;

const K: usize = 3;
const L: usize = 48;
/// Fixed majorization budget: deterministic work on every path.
const STEPS: usize = 1500;

/// A realizable serving problem: the landmark configuration IS a set of
/// points in R^K, and query deltas are exact Euclidean distances, so the
/// optimiser can recover the query position on any landmark subset.
fn landmark_setup() -> (Matrix, Vec<Box<[f32]>>) {
    let mut rng = Rng::new(0x5a4d);
    let config = Matrix::random_normal(&mut rng, L, K, 1.0);
    let vecs = (0..L)
        .map(|i| config.row(i).to_vec().into_boxed_slice())
        .collect();
    (config, vecs)
}

fn delta_to(config: &Matrix, q: &[f32]) -> Vec<f32> {
    (0..config.rows)
        .map(|i| {
            config
                .row(i)
                .iter()
                .zip(q)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt()
        })
        .collect()
}

fn builder(config: &Matrix, steps: usize) -> ServerBuilder<[f32]> {
    let (_, vecs) = landmark_setup();
    Server::builder(
        vecs,
        Arc::new(Euclidean),
        BackendOpt::replica_factory_budget(Backend::native(), config.clone(), steps),
    )
    .landmark_config(config.clone())
    .batcher(BatcherConfig {
        max_batch: 8,
        max_delay: Duration::from_millis(1),
        queue_cap: 256,
        frontend_threads: 2,
        replicas: 1,
    })
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn sharded_serving_matches_unsharded_within_partition_band() {
    let (config, _) = landmark_setup();
    let mut rng = Rng::new(0xbead);
    let queries: Vec<Vec<f32>> = (0..10)
        .map(|_| (0..K).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
        .collect();

    // unsharded reference embeddings
    let reference = builder(&config, STEPS).build().expect("valid configuration");
    let href = reference.handle();
    let ref_coords: Vec<Vec<f32>> = queries
        .iter()
        .map(|q| {
            href.submit(Request::delta(delta_to(&config, q)))
                .recv()
                .expect("reference query")
                .coords
        })
        .collect();
    drop(href);
    reference.shutdown();

    for shards in [1usize, 2, 4] {
        let server = builder(&config, STEPS)
            .shards(ShardConfig {
                shards,
                anchors: 12,
                opt_steps: STEPS,
                ..Default::default()
            })
            .build_sharded()
            .expect("valid sharded configuration");
        let h = server.handle();
        assert_eq!(h.shards(), shards, "L=48 splits cleanly into {shards}");
        // every landmark is owned by some shard; anchors lead each block
        let owned: std::collections::BTreeSet<usize> = (0..h.shards())
            .flat_map(|s| h.shard_landmarks(s).unwrap().to_vec())
            .collect();
        assert_eq!(owned.len(), L, "shards cover the landmark set");
        // S=1 is the whole landmark set in anchor-first order; S>1 pays
        // the divide-solve partition tolerance on top of that
        let band = if shards == 1 { 0.05 } else { 0.25 };
        for (q, want) in queries.iter().zip(&ref_coords) {
            let r = h
                .submit(Request::delta(delta_to(&config, q)))
                .recv()
                .expect("sharded query");
            assert!(!r.degraded, "all shards healthy: no degradation");
            let vs_ref = max_abs_diff(&r.coords, want);
            assert!(
                vs_ref < band,
                "S={shards}: sharded embedding {vs_ref} off the unsharded \
                 reference (band {band})"
            );
            let vs_true = max_abs_diff(&r.coords, q);
            assert!(
                vs_true < 0.35,
                "S={shards}: embedding {vs_true} away from the true point"
            );
        }
        let snap = h.metrics.snapshot();
        assert_eq!(snap.completed, queries.len() as u64);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.shards, shards as u64);
        assert_eq!(snap.degraded, 0);
        // per-shard pools actually did the solves
        let per_shard = h.shard_snapshots();
        assert_eq!(per_shard.len(), shards);
        for s in &per_shard {
            assert_eq!(s.completed, queries.len() as u64);
        }
        drop(h);
        server.shutdown();
    }
}

#[test]
fn killing_a_shard_mid_soak_degrades_but_keeps_serving() {
    let (config, _) = landmark_setup();
    let server = builder(&config, 120)
        .shards(ShardConfig {
            shards: 4,
            anchors: 12,
            opt_steps: 120,
            quorum: 2,
            shard_timeout: Duration::from_secs(10),
            ..Default::default()
        })
        .build_sharded()
        .expect("valid sharded configuration");
    let h = server.handle();
    let q = vec![0.3f32, -0.2, 0.5];
    let delta = delta_to(&config, &q);

    // concurrent soak; one shard dies partway through
    std::thread::scope(|scope| {
        for _ in 0..3 {
            let h = h.clone();
            let delta = delta.clone();
            scope.spawn(move || {
                for _ in 0..40 {
                    let r = h
                        .submit(Request::delta(delta.clone()))
                        .recv()
                        .expect("soak query must keep succeeding");
                    assert!(r.coords.iter().all(|c| c.is_finite()));
                }
            });
        }
        std::thread::sleep(Duration::from_millis(30));
        assert!(h.stop_shard(1), "first stop takes the queue");
        assert!(!h.stop_shard(1), "second stop is a no-op");
    });

    // steady state after the kill: still answering, flagged degraded
    for _ in 0..5 {
        let r = h
            .submit(Request::delta(delta.clone()))
            .recv()
            .expect("three live shards hold the quorum");
        assert!(r.degraded, "missing shard must flag degradation");
        assert!(max_abs_diff(&r.coords, &q) < 0.5, "estimate stays sane");
    }
    let snap = h.metrics.snapshot();
    assert_eq!(snap.completed, 3 * 40 + 5, "no query lost to the dead shard");
    assert_eq!(snap.failed, 0, "quorum held: accuracy cost, not availability");
    assert!(snap.degraded >= 5, "degraded replies surface in metrics");
    assert!(snap.shard_failures >= 5, "dead-shard dispatches are counted");
    drop(h);
    server.shutdown();
}

#[test]
fn losing_the_quorum_fails_with_a_typed_error_not_a_hang() {
    let (config, _) = landmark_setup();
    let server = builder(&config, 80)
        .shards(ShardConfig {
            shards: 3,
            anchors: 12,
            opt_steps: 80,
            quorum: 2,
            shard_timeout: Duration::from_secs(5),
            ..Default::default()
        })
        .build_sharded()
        .expect("valid sharded configuration");
    let h = server.handle();
    let delta = delta_to(&config, &[0.1, 0.2, -0.3]);
    assert!(h.submit(Request::delta(delta.clone())).recv().is_ok());
    assert!(h.stop_shard(0));
    assert!(h.stop_shard(2));
    // one live shard < quorum of 2: fast typed failure
    let err = h.submit(Request::delta(delta)).recv();
    match err {
        Err(ServeError::ShardUnavailable { .. }) => {}
        other => panic!("expected ShardUnavailable, got {other:?}"),
    }
    let snap = h.metrics.snapshot();
    assert_eq!(snap.failed, 1);
    assert!(snap.shard_failures >= 2);
    drop(h);
    server.shutdown();
}

#[test]
fn object_queries_route_through_the_shards() {
    let (config, _) = landmark_setup();
    let server = builder(&config, STEPS)
        .shards(ShardConfig {
            shards: 2,
            anchors: 12,
            opt_steps: STEPS,
            ..Default::default()
        })
        .build_sharded()
        .expect("valid sharded configuration");
    let h = server.handle();
    // the frontend computes the delta row from the raw object
    let q = vec![0.4f32, -0.1, 0.2];
    let r = h.submit(Request::object(q.clone())).recv().expect("object query");
    assert!(!r.degraded);
    assert!(max_abs_diff(&r.coords, &q) < 0.35);
    // malformed deltas are rejected with a typed error, not dispatched
    let err = h.submit(Request::delta(vec![1.0; L + 1])).recv();
    match err {
        Err(ServeError::BadInput { reason }) => {
            assert!(reason.contains("one per landmark"), "{reason}");
        }
        other => panic!("expected BadInput, got {other:?}"),
    }
    drop(h);
    server.shutdown();
}

#[test]
fn build_sharded_requires_a_landmark_configuration() {
    let (config, vecs) = landmark_setup();
    let b = Server::builder(
        vecs,
        Arc::new(Euclidean),
        BackendOpt::replica_factory_budget(Backend::native(), config, 50),
    );
    match b.build_sharded() {
        Err(ServeError::BadInput { reason }) => {
            assert!(reason.contains("landmark_config"), "{reason}");
        }
        Ok(_) => panic!("sharding without a landmark configuration must fail"),
        Err(other) => panic!("expected BadInput, got {other:?}"),
    }
}
