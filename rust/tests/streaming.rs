//! Chunk-invariance contract of the bounded-memory streaming pipeline:
//! for a fixed step budget the streamed output must equal the monolithic
//! `cross_matrix` + backend path **bit-for-bit** for the optimisation
//! method (row-independent majorization) and to 1e-6 for the MLP method,
//! for every chunk shape — including chunk = 1, a ragged final chunk,
//! chunk = N and N < chunk.

use lmds_ose::coordinator::methods::{BackendNn, BackendOpt};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::cross_matrix;
use lmds_ose::mds::Matrix;
use lmds_ose::nn::{MlpParams, MlpShape};
use lmds_ose::ose::pipeline::embed_stream;
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Levenshtein;
use lmds_ose::util::prng::Rng;

const N: usize = 100;
const L: usize = 20;
const K: usize = 3;

/// Chunk shapes required by the acceptance criteria: 1, a size that leaves
/// a ragged final chunk (100 % 7 == 2), one mid-size ragged (100 % 64 ==
/// 36), exactly N, and N < chunk.
const CHUNKS: [usize; 5] = [1, 7, 64, N, N + 50];

fn dataset() -> (Vec<String>, Vec<String>, Matrix) {
    let mut geco = Geco::new(GecoConfig { seed: 0x5c, ..Default::default() });
    let all = geco.generate_unique(N + L);
    let queries = all[..N].to_vec();
    let landmarks = all[N..].to_vec();
    let mut rng = Rng::new(0x5d);
    let lm_config = Matrix::random_normal(&mut rng, L, K, 1.0);
    (queries, landmarks, lm_config)
}

#[test]
fn opt_streaming_is_chunk_invariant_bit_for_bit() {
    let (queries, landmarks, lm_config) = dataset();
    let q_refs: Vec<&str> = queries.iter().map(|s| s.as_str()).collect();
    let lm_refs: Vec<&str> = landmarks.iter().map(|s| s.as_str()).collect();

    // monolithic oracle: full N x L matrix, one embed call. Fixed step
    // budget (rel_tol = 0) so early stopping cannot depend on batch
    // composition — the precondition for bit-exact chunk invariance.
    let mut mono_method = BackendOpt::with_defaults(Backend::native(), lm_config.clone());
    mono_method.total_steps = 50;
    mono_method.rel_tol = 0.0;
    let delta = cross_matrix(&q_refs, &lm_refs, &Levenshtein);
    let mono = mono_method.embed(&delta).unwrap();

    for chunk in CHUNKS {
        let mut method = BackendOpt::with_defaults(Backend::native(), lm_config.clone());
        method.total_steps = 50;
        method.rel_tol = 0.0;
        let (streamed, stats) =
            embed_stream(&q_refs, &lm_refs, &Levenshtein, &mut method, chunk).unwrap();
        assert_eq!((streamed.rows, streamed.cols), (N, K), "chunk {chunk}");
        assert_eq!(
            streamed.data, mono.data,
            "chunk {chunk}: opt streaming must be bit-for-bit"
        );
        assert_eq!(stats.rows, N);
        assert_eq!(stats.chunks, N.div_ceil(chunk));
        assert!(stats.max_chunk_rows <= chunk, "chunk {chunk}");
    }
}

#[test]
fn nn_streaming_is_chunk_invariant() {
    let (queries, landmarks, _) = dataset();
    let q_refs: Vec<&str> = queries.iter().map(|s| s.as_str()).collect();
    let lm_refs: Vec<&str> = landmarks.iter().map(|s| s.as_str()).collect();
    let mut rng = Rng::new(0x5e);
    let params = MlpParams::init(
        &MlpShape { input: L, hidden: [16, 16, 8], output: K },
        &mut rng,
    );

    let mut mono_method = BackendNn::new(Backend::native(), params.clone());
    let delta = cross_matrix(&q_refs, &lm_refs, &Levenshtein);
    let mono = mono_method.embed(&delta).unwrap();

    for chunk in CHUNKS {
        let mut method = BackendNn::new(Backend::native(), params.clone());
        let (streamed, stats) =
            embed_stream(&q_refs, &lm_refs, &Levenshtein, &mut method, chunk).unwrap();
        let diff = mono.max_abs_diff(&streamed);
        assert!(
            diff < 1e-6,
            "chunk {chunk}: nn streaming diverges by {diff}"
        );
        assert_eq!(stats.chunks, N.div_ceil(chunk));
    }
}

#[test]
fn single_object_stream_matches_monolithic() {
    // N = 1 with every chunk shape: the smallest ragged case
    let (queries, landmarks, lm_config) = dataset();
    let one: Vec<&str> = vec![queries[0].as_str()];
    let lm_refs: Vec<&str> = landmarks.iter().map(|s| s.as_str()).collect();
    let mut mono_method = BackendOpt::with_defaults(Backend::native(), lm_config.clone());
    mono_method.rel_tol = 0.0;
    let delta = cross_matrix(&one, &lm_refs, &Levenshtein);
    let mono = mono_method.embed(&delta).unwrap();
    for chunk in [1usize, 2, 64] {
        let mut method = BackendOpt::with_defaults(Backend::native(), lm_config.clone());
        method.rel_tol = 0.0;
        let (streamed, stats) =
            embed_stream(&one, &lm_refs, &Levenshtein, &mut method, chunk).unwrap();
        assert_eq!(streamed.data, mono.data, "chunk {chunk}");
        assert_eq!(stats.chunks, 1);
        assert_eq!(stats.max_chunk_rows, 1);
    }
}
