//! Integration: the full two-stage pipeline over the PJRT runtime,
//! exercising landmark selection -> LSMDS artifact -> NN training artifact
//! -> OSE artifact as one composition (plus pure-Rust parity checks).

use std::sync::Mutex;

use once_cell::sync::Lazy;

use lmds_ose::coordinator::embedder::{embed_dataset, OseBackend, PipelineConfig};
use lmds_ose::coordinator::trainer::TrainConfig;
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::cross_matrix;
use lmds_ose::mds::stress::total_error;
use lmds_ose::mds::LsmdsConfig;
use lmds_ose::runtime::{default_artifact_dir, RuntimeHandle, RuntimeThread};
use lmds_ose::strdist::Levenshtein;

static RT: Lazy<Option<Mutex<RuntimeThread>>> = Lazy::new(|| {
    RuntimeThread::spawn(&default_artifact_dir()).ok().map(Mutex::new)
});

fn handle() -> Option<RuntimeHandle> {
    RT.as_ref().map(|m| m.lock().unwrap().handle())
}

fn smoke_cfg(backend: OseBackend) -> PipelineConfig {
    PipelineConfig {
        dim: 7,
        landmarks: 32,
        backend,
        hidden: [32, 16, 8], // matches the smoke artifacts
        lsmds: LsmdsConfig { dim: 7, max_iters: 100, ..Default::default() },
        train: TrainConfig { epochs: 40, ..Default::default() },
        ..Default::default()
    }
}

fn names(n: usize, seed: u64) -> Vec<String> {
    let mut geco = Geco::new(GecoConfig { seed, ..Default::default() });
    geco.generate_unique(n)
}

#[test]
fn pjrt_pipeline_nn_backend_end_to_end() {
    let Some(h) = handle() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let names = names(150, 21);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let mut r =
        embed_dataset(&objs, &Levenshtein, &smoke_cfg(OseBackend::Nn), Some(&h))
            .unwrap();
    // the PJRT paths must actually have been taken
    assert_eq!(r.method.name(), "nn-pjrt");
    assert_eq!(r.coords.rows, 150);
    assert!(r.coords.data.iter().all(|v| v.is_finite()));
    // the returned method serves fresh queries through the artifact
    let lm_names: Vec<&str> = r.landmark_idx.iter().map(|&i| objs[i]).collect();
    let q = cross_matrix(&["john smith", "jessica nguyen"], &lm_names, &Levenshtein);
    let y = r.method.embed(&q).unwrap();
    assert_eq!((y.rows, y.cols), (2, 7));
}

#[test]
fn pjrt_pipeline_opt_backend_end_to_end() {
    let Some(h) = handle() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let names = names(150, 22);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let mut r =
        embed_dataset(&objs, &Levenshtein, &smoke_cfg(OseBackend::Opt), Some(&h))
            .unwrap();
    assert_eq!(r.method.name(), "opt-pjrt");
    assert_eq!(r.coords.rows, 150);
    assert!(r.coords.data.iter().all(|v| v.is_finite()));
    let lm_names: Vec<&str> = r.landmark_idx.iter().map(|&i| objs[i]).collect();
    let q = cross_matrix(&["maria garcia"], &lm_names, &Levenshtein);
    let y = r.method.embed(&q).unwrap();
    assert_eq!((y.rows, y.cols), (1, 7));
}

#[test]
fn pjrt_and_rust_opt_backends_agree_on_quality() {
    let Some(h) = handle() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let all = names(180, 23);
    let (train, test) = all.split_at(150);
    let objs: Vec<&str> = train.iter().map(|s| s.as_str()).collect();
    let cfg = smoke_cfg(OseBackend::Opt);

    let mut with_pjrt = embed_dataset(&objs, &Levenshtein, &cfg, Some(&h)).unwrap();
    let mut rust_only = embed_dataset(&objs, &Levenshtein, &cfg, None).unwrap();
    assert_eq!(with_pjrt.method.name(), "opt-pjrt");
    assert_eq!(rust_only.method.name(), "opt-rust");

    // score both pipelines' OSE on held-out queries against their own
    // configurations: quality (total error) must be comparable
    let score = |r: &mut lmds_ose::coordinator::PipelineResult| {
        let lm_names: Vec<&str> =
            r.landmark_idx.iter().map(|&i| objs[i]).collect();
        let test_refs: Vec<&str> = test.iter().map(|s| s.as_str()).collect();
        let q = cross_matrix(&test_refs, &lm_names, &Levenshtein);
        let y = r.method.embed(&q).unwrap();
        let delta_new = cross_matrix(
            &test_refs,
            &objs.iter().copied().collect::<Vec<_>>(),
            &Levenshtein,
        );
        total_error(&r.coords, &delta_new, &y)
    };
    let e_pjrt = score(&mut with_pjrt);
    let e_rust = score(&mut rust_only);
    assert!(e_pjrt.is_finite() && e_rust.is_finite());
    // different inits/configs, same algorithm family: within 2x
    assert!(
        e_pjrt < 2.0 * e_rust + 1.0 && e_rust < 2.0 * e_pjrt + 1.0,
        "quality diverges: pjrt {e_pjrt} vs rust {e_rust}"
    );
}

#[test]
fn pipeline_deterministic_for_seed() {
    // pure-Rust path: identical seeds must give identical coordinates
    let names = names(100, 24);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let cfg = smoke_cfg(OseBackend::Opt);
    let a = embed_dataset(&objs, &Levenshtein, &cfg, None).unwrap();
    let b = embed_dataset(&objs, &Levenshtein, &cfg, None).unwrap();
    assert_eq!(a.landmark_idx, b.landmark_idx);
    assert_eq!(a.coords.data, b.coords.data);
}
