//! Integration: the full two-stage pipeline through the compute-backend
//! seam — landmark selection -> LSMDS -> (NN training | batched OSE) as
//! one composition. Runs entirely on the native backend (no artifacts, no
//! XLA toolchain); with `--features pjrt` an extra module exercises the
//! PJRT backend when its artifacts are available.

use lmds_ose::coordinator::embedder::{embed_dataset, OseBackend, PipelineConfig};
use lmds_ose::coordinator::trainer::TrainConfig;
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::cross_matrix;
use lmds_ose::mds::stress::total_error;
use lmds_ose::mds::LsmdsConfig;
use lmds_ose::ose::OseMethod;
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Levenshtein;

fn smoke_cfg(backend: OseBackend) -> PipelineConfig {
    PipelineConfig {
        dim: 7,
        landmarks: 32,
        backend,
        hidden: [32, 16, 8], // matches the smoke artifacts
        lsmds: LsmdsConfig { dim: 7, max_iters: 100, ..Default::default() },
        train: TrainConfig { epochs: 40, ..Default::default() },
        ..Default::default()
    }
}

fn names(n: usize, seed: u64) -> Vec<String> {
    let mut geco = Geco::new(GecoConfig { seed, ..Default::default() });
    geco.generate_unique(n)
}

#[test]
fn native_pipeline_nn_backend_end_to_end() {
    let backend = Backend::native();
    let names = names(150, 21);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let mut r =
        embed_dataset(&objs, &Levenshtein, &smoke_cfg(OseBackend::Nn), &backend)
            .unwrap();
    assert_eq!(r.method.name(), "nn-native");
    assert_eq!(r.coords.rows, 150);
    assert!(r.coords.data.iter().all(|v| v.is_finite()));
    // the returned method serves fresh queries through the backend
    let lm_names: Vec<&str> = r.landmark_idx.iter().map(|&i| objs[i]).collect();
    let q = cross_matrix(&["john smith", "jessica nguyen"], &lm_names, &Levenshtein);
    let y = r.method.embed(&q).unwrap();
    assert_eq!((y.rows, y.cols), (2, 7));
}

#[test]
fn native_pipeline_opt_backend_end_to_end() {
    let backend = Backend::native();
    let names = names(150, 22);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let mut r =
        embed_dataset(&objs, &Levenshtein, &smoke_cfg(OseBackend::Opt), &backend)
            .unwrap();
    assert_eq!(r.method.name(), "opt-native");
    assert_eq!(r.coords.rows, 150);
    assert!(r.coords.data.iter().all(|v| v.is_finite()));
    let lm_names: Vec<&str> = r.landmark_idx.iter().map(|&i| objs[i]).collect();
    let q = cross_matrix(&["maria garcia"], &lm_names, &Levenshtein);
    let y = r.method.embed(&q).unwrap();
    assert_eq!((y.rows, y.cols), (1, 7));
}

#[test]
fn held_out_quality_is_finite_and_reasonable() {
    // score the OSE on held-out queries against the pipeline's own
    // configuration: total error must be finite and not absurd
    let backend = Backend::native();
    let all = names(180, 23);
    let (train, test) = all.split_at(150);
    let objs: Vec<&str> = train.iter().map(|s| s.as_str()).collect();
    let cfg = smoke_cfg(OseBackend::Opt);
    let mut r = embed_dataset(&objs, &Levenshtein, &cfg, &backend).unwrap();

    let lm_names: Vec<&str> = r.landmark_idx.iter().map(|&i| objs[i]).collect();
    let test_refs: Vec<&str> = test.iter().map(|s| s.as_str()).collect();
    let q = cross_matrix(&test_refs, &lm_names, &Levenshtein);
    let y = r.method.embed(&q).unwrap();
    let delta_new = cross_matrix(&test_refs, &objs, &Levenshtein);
    let err = total_error(&r.coords, &delta_new, &y);
    assert!(err.is_finite() && err >= 0.0, "total error {err}");
    // 30 held-out points against 150 refs: a degenerate embedding (all
    // points at one spot) scores in the thousands on this data
    assert!(err < 10_000.0, "quality collapsed: Err(m) = {err}");
}

#[test]
fn pipeline_deterministic_for_seed() {
    // native backend: identical seeds must give identical coordinates
    let backend = Backend::native();
    let names = names(100, 24);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let cfg = smoke_cfg(OseBackend::Opt);
    let a = embed_dataset(&objs, &Levenshtein, &cfg, &backend).unwrap();
    let b = embed_dataset(&objs, &Levenshtein, &cfg, &backend).unwrap();
    assert_eq!(a.landmark_idx, b.landmark_idx);
    assert_eq!(a.coords.data, b.coords.data);
}

/// PJRT backend integration (feature-gated; skips when the artifacts or
/// real XLA bindings are unavailable, e.g. under the in-tree stub).
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use lmds_ose::runtime::default_artifact_dir;

    #[test]
    fn pjrt_pipeline_end_to_end_or_skip() {
        let Ok(backend) = Backend::pjrt(&default_artifact_dir()) else {
            eprintln!("skipping: PJRT backend unavailable (artifacts/bindings)");
            return;
        };
        let names = names(150, 25);
        let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let mut r =
            embed_dataset(&objs, &Levenshtein, &smoke_cfg(OseBackend::Opt), &backend)
                .unwrap();
        assert_eq!(r.method.name(), "opt-pjrt");
        assert_eq!(r.coords.rows, 150);
        assert!(r.coords.data.iter().all(|v| v.is_finite()));
        // quality parity with the native backend on the same config
        let native = embed_dataset(
            &objs,
            &Levenshtein,
            &smoke_cfg(OseBackend::Opt),
            &Backend::native(),
        )
        .unwrap();
        let lm_names: Vec<&str> = r.landmark_idx.iter().map(|&i| objs[i]).collect();
        let q = cross_matrix(&["probe query"], &lm_names, &Levenshtein);
        let y = r.method.embed(&q).unwrap();
        assert_eq!((y.rows, y.cols), (1, 7));
        assert!((r.landmark_stress - native.landmark_stress).abs() < 0.1);
    }
}
