//! Golden-seed regression for the eval protocol: one small Figure-1-style
//! run with pinned seeds on the native backend, asserted two ways so
//! future kernel changes cannot silently shift numerics:
//!
//! 1. **Tight bands on geometry-backed runs.** On realizable synthetic
//!    data the correct raw/normalized stress and per-point OSE error are
//!    known a priori (≈ 0), so the bands are tight without baking in
//!    implementation-specific constants a toolchain bump would invalidate.
//! 2. **Bit-exact determinism.** The whole run (landmark selection, LSMDS
//!    through the compute backend, OSE) is seeded; two executions must
//!    agree to the last bit. Any unseeded nondeterminism a kernel rewrite
//!    introduces (e.g. order-dependent parallel reductions) fails here.

use lmds_ose::coordinator::embedder::lsmds_landmarks;
use lmds_ose::coordinator::methods::BackendOpt;
use lmds_ose::data::synthetic::gaussian_clusters;
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::{cross_matrix, full_matrix};
use lmds_ose::mds::landmarks::fps_landmarks;
use lmds_ose::mds::stress::{normalized_stress, point_error, raw_stress};
use lmds_ose::mds::{LsmdsConfig, Matrix};
use lmds_ose::ose::OseMethod;
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::{Euclidean, Levenshtein};
use lmds_ose::util::prng::Rng;

/// One pinned-seed realizable run: LSMDS on L landmark points whose
/// dissimilarities are exact 3-D Euclidean distances, then OSE of held-out
/// points from their exact landmark distances.
fn realizable_run() -> (Matrix, f64, f64, Matrix, Vec<f64>) {
    let l = 60usize;
    let m = 10usize;
    let dim = 3usize;
    let mut rng = Rng::new(0x901d);
    // a single Gaussian blob: the classic easy MDS geometry, so the run
    // converges to ~zero stress from any seeded init (no cluster-induced
    // local minima to make the band flaky)
    let all = gaussian_clusters(&mut rng, l + m, dim, 1, 1.0);
    let lm_pts: Vec<&[f32]> = all[..l].iter().map(|p| p.as_slice()).collect();
    let new_pts: Vec<&[f32]> = all[l..].iter().map(|p| p.as_slice()).collect();

    let delta_ll = full_matrix(&lm_pts, &Euclidean);
    let lcfg = LsmdsConfig {
        dim,
        max_iters: 1500,
        rel_tol: 1e-12,
        seed: 0x5eed,
        ..Default::default()
    };
    let backend = Backend::native();
    let (config, norm_stress) = lsmds_landmarks(&delta_ll, &lcfg, &backend).unwrap();
    let raw = raw_stress(&config, &delta_ll);

    let delta_ml = cross_matrix(&new_pts, &lm_pts, &Euclidean);
    let mut method = BackendOpt::with_defaults(backend, config.clone());
    method.total_steps = 1000;
    method.rel_tol = 0.0;
    let y = method.embed(&delta_ml).unwrap();
    let perrs: Vec<f64> = (0..m)
        .map(|j| point_error(&config, delta_ml.row(j), y.row(j)))
        .collect();
    (config, raw, norm_stress, y, perrs)
}

#[test]
fn golden_realizable_run_stays_in_band() {
    let (_, raw, norm, y, perrs) = realizable_run();
    // realizable deltas: LSMDS must essentially solve the problem. The
    // bands are set by geometry + f32 precision, not by any pinned
    // implementation constant, so they are tight AND stable.
    assert!(norm < 0.05, "normalized stress {norm} out of band [0, 0.05)");
    assert!(raw.is_finite() && raw >= 0.0);
    // raw stress consistent with the normalized value (same residuals)
    assert!(raw < 50.0, "raw stress {raw} out of band");
    assert!(y.data.iter().all(|v| v.is_finite()));
    // held-out points have exact landmark distances: the optimiser must
    // place each within a small Eq.-2 residual of the landmark geometry.
    // Zero-vector placement scores in the hundreds on this data.
    for (j, p) in perrs.iter().enumerate() {
        assert!(*p < 5.0, "point {j}: PErr {p} out of band [0, 5)");
    }
}

#[test]
fn golden_realizable_run_is_bit_deterministic() {
    let (c1, r1, n1, y1, p1) = realizable_run();
    let (c2, r2, n2, y2, p2) = realizable_run();
    assert_eq!(c1.data, c2.data, "landmark config must be bit-deterministic");
    assert_eq!(y1.data, y2.data, "OSE coords must be bit-deterministic");
    assert!(r1 == r2 && n1 == n2, "stress must be bit-deterministic");
    assert_eq!(p1, p2);
}

/// The Figure-1-shaped string run: Geco names, FPS landmarks, Levenshtein,
/// LSMDS + opt-OSE of held-out names — the same composition the eval
/// protocol uses, at smoke scale with pinned seeds.
fn string_run() -> (Vec<usize>, Matrix, f64, Matrix) {
    let n = 120usize;
    let m = 20usize;
    let l = 40usize;
    let dim = 7usize;
    let mut geco = Geco::new(GecoConfig { seed: 0x901e, ..Default::default() });
    let all = geco.generate_unique(n + m);
    let refs: Vec<&str> = all[..n].iter().map(|s| s.as_str()).collect();
    let news: Vec<&str> = all[n..].iter().map(|s| s.as_str()).collect();

    let mut rng = Rng::new(0xFA5);
    let lm_idx = fps_landmarks(&mut rng, &refs, l, &Levenshtein);
    let lm_objs: Vec<&str> = lm_idx.iter().map(|&i| refs[i]).collect();
    let delta_ll = full_matrix(&lm_objs, &Levenshtein);
    let lcfg = LsmdsConfig { dim, max_iters: 150, seed: 0x5eed, ..Default::default() };
    let backend = Backend::native();
    let (config, norm) = lsmds_landmarks(&delta_ll, &lcfg, &backend).unwrap();

    let delta_ml = cross_matrix(&news, &lm_objs, &Levenshtein);
    let mut method = BackendOpt::with_defaults(backend, config.clone());
    method.rel_tol = 0.0;
    let y = method.embed(&delta_ml).unwrap();
    (lm_idx, config, norm, y)
}

#[test]
fn golden_string_run_stays_in_band() {
    let (lm_idx, config, norm, y) = string_run();
    assert_eq!(lm_idx.len(), 40);
    // Levenshtein on names is not realizable in R^7, but a 40-landmark
    // LSMDS at K=7 lands well under 0.5 normalized stress on Geco data —
    // collapse (or a sign/step regression) blows straight through this
    assert!(
        norm > 1e-4 && norm < 0.5,
        "normalized stress {norm} out of band (1e-4, 0.5)"
    );
    let mut geco = Geco::new(GecoConfig { seed: 0x901e, ..Default::default() });
    let all = geco.generate_unique(140);
    let refs: Vec<&str> = all[..120].iter().map(|s| s.as_str()).collect();
    let news: Vec<&str> = all[120..].iter().map(|s| s.as_str()).collect();
    let lm_objs: Vec<&str> = lm_idx.iter().map(|&i| refs[i]).collect();
    let delta_ml = cross_matrix(&news, &lm_objs, &Levenshtein);
    let origin = vec![0.0f32; 7];
    let mut norm_perrs = Vec::new();
    for j in 0..y.rows {
        let embedded = point_error(&config, delta_ml.row(j), y.row(j));
        let at_origin = point_error(&config, delta_ml.row(j), &origin);
        // the optimiser starts AT the origin and majorization is monotone
        // in the Eq.-2 objective (== PErr over the landmarks), so this
        // holds by construction; a step-sign or warm-start regression
        // breaks it immediately
        assert!(
            embedded <= at_origin * (1.0 + 1e-9) + 1e-9,
            "query {j}: PErr {embedded} worse than its own start {at_origin}"
        );
        let denom: f64 = delta_ml.row(j).iter().map(|d| *d as f64).sum();
        norm_perrs.push(embedded / denom.max(1e-30));
    }
    // coarse normalized-PErr sanity band (string data is not realizable,
    // so the tight bands live in the realizable golden run above): a
    // collapsed or diverged embedding scores far outside this
    for (j, p) in norm_perrs.iter().enumerate() {
        assert!(p.is_finite() && *p < 5.0, "query {j}: normalized PErr {p}");
    }
    let mean = norm_perrs.iter().sum::<f64>() / norm_perrs.len() as f64;
    assert!(mean < 2.0, "mean normalized PErr {mean} out of band [0, 2)");
}

#[test]
fn golden_string_run_is_bit_deterministic() {
    let (i1, c1, n1, y1) = string_run();
    let (i2, c2, n2, y2) = string_run();
    assert_eq!(i1, i2);
    assert_eq!(c1.data, c2.data);
    assert!(n1 == n2);
    assert_eq!(y1.data, y2.data);
}

#[test]
fn golden_normalized_stress_consistent_with_raw() {
    // the two stress numbers the protocol reports must describe the same
    // residuals: normalized == sqrt(raw / sum delta^2)
    let (_, config, norm, _) = string_run();
    let mut geco = Geco::new(GecoConfig { seed: 0x901e, ..Default::default() });
    let all = geco.generate_unique(140);
    let refs: Vec<&str> = all[..120].iter().map(|s| s.as_str()).collect();
    let mut rng = Rng::new(0xFA5);
    let lm_idx = fps_landmarks(&mut rng, &refs, 40, &Levenshtein);
    let lm_objs: Vec<&str> = lm_idx.iter().map(|&i| refs[i]).collect();
    let delta_ll = full_matrix(&lm_objs, &Levenshtein);
    let raw = raw_stress(&config, &delta_ll);
    let norm2 = normalized_stress(&config, &delta_ll);
    assert!((norm - norm2).abs() < 1e-12, "{norm} vs {norm2}");
    let mut den = 0.0f64;
    for i in 0..delta_ll.rows {
        for j in (i + 1)..delta_ll.cols {
            den += (delta_ll.at(i, j) as f64).powi(2);
        }
    }
    assert!(((raw / den).sqrt() - norm).abs() < 1e-12);
}
