//! Integration: the replicated streaming OSE service over backend-generic
//! methods — requests flow frontend -> dispatch queue -> executor replica
//! pool -> compute backend and back. Runs on the native backend
//! unconditionally, so CI exercises the whole serving path without
//! artifacts. Includes the fault-injection suite: a panicking replica must
//! fail only its own batch, restart from the factory, and leave every
//! handle answering.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use lmds_ose::coordinator::methods::BackendNn;
use lmds_ose::coordinator::{
    BatcherConfig, Request, ServeError, Server, ServerBuilder,
};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::Matrix;
use lmds_ose::nn::{MlpParams, MlpShape};
use lmds_ose::ose::{factory_fn, OseMethod, OseMethodFactory, RustOptimise};
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::{Euclidean, Levenshtein};
use lmds_ose::util::prng::Rng;

fn test_params() -> MlpParams {
    let mut rng = Rng::new(31);
    MlpParams::init(
        &MlpShape { input: 32, hidden: [32, 16, 8], output: 7 },
        &mut rng,
    )
}

fn start_backend_server(backend: Backend, max_batch: usize, replicas: usize) -> Server<str> {
    let mut geco = Geco::new(GecoConfig { seed: 77, ..Default::default() });
    let landmarks = geco.generate_unique(32);
    ServerBuilder::strings(
        landmarks,
        Arc::new(Levenshtein),
        BackendNn::replica_factory(backend, test_params()),
    )
    .batcher(BatcherConfig {
        max_batch,
        max_delay: Duration::from_millis(2),
        queue_cap: 512,
        frontend_threads: 2,
        replicas,
    })
    .build()
    .expect("valid server configuration")
}

#[test]
fn backend_service_serves_queries() {
    let server = start_backend_server(Backend::native(), 8, 1);
    let sh = server.handle();
    let mut geco = Geco::new(GecoConfig { seed: 78, ..Default::default() });
    let tickets: Vec<_> = (0..100)
        .map(|_| sh.submit(Request::object(geco.sample_name())))
        .collect();
    for t in tickets {
        let r = t.recv().unwrap();
        assert_eq!(r.coords.len(), 7);
        assert!(r.coords.iter().all(|c| c.is_finite()));
        assert!(!r.degraded, "unsharded serving never degrades");
    }
    let snap = sh.metrics.snapshot();
    assert_eq!(snap.completed, 100);
    assert_eq!(snap.failed, 0);
    drop(sh);
    server.shutdown();
}

#[test]
fn backend_service_batches_and_is_deterministic() {
    let server = start_backend_server(Backend::native(), 8, 4);
    let sh = server.handle();
    // identical queries must give identical coordinates regardless of the
    // batch OR the replica they landed in (composition must not leak)
    let t1: Vec<_> = (0..16).map(|_| sh.submit(Request::object("anna smith"))).collect();
    let first: Vec<Vec<f32>> = t1
        .into_iter()
        .map(|t| t.recv().unwrap().coords)
        .collect();
    for c in &first {
        assert_eq!(c, &first[0]);
    }
    // and a lone straggler (batch of 1) agrees too
    std::thread::sleep(Duration::from_millis(10));
    let solo = sh.submit(Request::object("anna smith")).recv().unwrap();
    let max_diff = solo
        .coords
        .iter()
        .zip(first[0].iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "batching leaked into results: {max_diff}");
    drop(sh);
    server.shutdown();
}

#[test]
fn service_single_query_latency_under_paper_bound() {
    // paper Sec. 6: NN maps a new point in < 1 ms. Measure the steady-state
    // single-query path (batcher delay excluded: use max_delay=0-ish).
    let mut geco = Geco::new(GecoConfig { seed: 79, ..Default::default() });
    let landmarks = geco.generate_unique(32);
    let server = ServerBuilder::strings(
        landmarks,
        Arc::new(Levenshtein),
        BackendNn::replica_factory(Backend::native(), test_params()),
    )
    .batcher(BatcherConfig {
        max_batch: 1,
        max_delay: Duration::from_micros(100),
        queue_cap: 64,
        frontend_threads: 1,
        replicas: 1,
    })
    .build()
    .expect("valid server configuration");
    let sh = server.handle();
    // warm caches and the thread pool
    for _ in 0..20 {
        sh.submit(Request::object("warmup query")).recv().unwrap();
    }
    let mut lat = Vec::new();
    for i in 0..50 {
        let r = sh.submit(Request::object(format!("query {i}"))).recv().unwrap();
        lat.push(r.latency.as_secs_f64());
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat[lat.len() / 2];
    // generous CI bound; the bench harness reports the tight number
    assert!(p50 < 0.05, "p50 single-query latency {p50}s");
    drop(sh);
    server.shutdown();
}

/// An OSE method that panics whenever a delta row carries the poison
/// marker (NaN in column 0) — the fault-injection vehicle.
struct PanickyNn {
    inner: BackendNn,
}

impl OseMethod for PanickyNn {
    fn embed(&mut self, deltas: &Matrix) -> anyhow::Result<Matrix> {
        for r in 0..deltas.rows {
            if deltas.at(r, 0).is_nan() {
                panic!("poison batch (injected fault)");
            }
        }
        self.inner.embed(deltas)
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn landmarks(&self) -> usize {
        self.inner.landmarks()
    }

    fn name(&self) -> &'static str {
        "panicky-nn"
    }
}

#[test]
fn panicking_replica_fails_only_its_batch_and_restarts() {
    let builds = Arc::new(AtomicUsize::new(0));
    let factory: Arc<dyn OseMethodFactory> = {
        let builds = Arc::clone(&builds);
        let params = test_params();
        factory_fn(move || {
            builds.fetch_add(1, Ordering::SeqCst);
            Box::new(PanickyNn {
                inner: BackendNn::new(Backend::native(), params.clone()),
            })
        })
    };
    let mut geco = Geco::new(GecoConfig { seed: 80, ..Default::default() });
    let landmarks = geco.generate_unique(32);
    let server = ServerBuilder::strings(landmarks, Arc::new(Levenshtein), factory)
        .batcher(BatcherConfig {
            max_batch: 4,
            max_delay: Duration::from_millis(1),
            queue_cap: 256,
            frontend_threads: 2,
            replicas: 4,
        })
        .build()
        .expect("valid server configuration");
    let h = server.handle();
    let builds_before_poison = builds.load(Ordering::SeqCst);
    assert_eq!(builds_before_poison, 4, "one replica per executor");

    // a healthy warmup round on every handle
    for i in 0..8 {
        assert!(h.submit(Request::object(format!("warm {i}"))).recv().is_ok());
    }

    // inject the poison batch: only ITS callers may see errors
    let mut poison = vec![1.0f32; 32];
    poison[0] = f32::NAN;
    let err = h.submit(Request::delta(poison)).recv();
    let e = err.expect_err("poisoned batch must get an error reply");
    match &e {
        ServeError::ReplicaPanic { reason } => {
            assert!(reason.contains("poison"), "caller sees the panic reason: {reason}");
        }
        other => panic!("expected ReplicaPanic, got {other:?}"),
    }
    assert!(e.to_string().contains("panicked"), "{e}");
    // the restart is recorded just after the error replies go out; give the
    // executor a bounded moment to finish rebuilding before asserting
    let t0 = std::time::Instant::now();
    while h.metrics.snapshot().replica_restarts < 1 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "replica restart never recorded"
        );
        std::thread::sleep(Duration::from_millis(1));
    }

    // the service keeps answering on every handle, from all client threads
    let handles: Vec<_> = (0..4).map(|_| h.clone()).collect();
    std::thread::scope(|scope| {
        for (c, hc) in handles.iter().enumerate() {
            scope.spawn(move || {
                for i in 0..25 {
                    let r = hc.submit(Request::object(format!("after poison {c}-{i}"))).recv();
                    assert!(r.is_ok(), "query after panic failed: {r:?}");
                }
            });
        }
    });

    let snap = h.metrics.snapshot();
    assert_eq!(snap.panics, 1, "exactly one poisoned batch");
    assert_eq!(snap.replica_restarts, 1, "the poisoned replica restarted");
    assert_eq!(
        builds.load(Ordering::SeqCst),
        builds_before_poison + 1,
        "restart went through the factory"
    );
    assert_eq!(snap.failed, 1, "only the poisoned batch failed");
    assert_eq!(snap.completed, 8 + 100);
    assert_eq!(snap.replicas, 4);
    // bounded-memory guarantee holds through the fault path too
    assert_eq!(snap.metrics_footprint, h.metrics.footprint());
    drop(handles);
    drop(h);
    server.shutdown();
}

#[test]
fn numeric_vector_workload_serves_through_the_generic_path() {
    // the paper's serving story for non-string objects: landmark vectors
    // with Euclidean dissimilarity, optimisation OSE — same Server type
    let mut rng = Rng::new(9);
    let l = 24;
    let k = 3;
    let landmark_config = Matrix::random_normal(&mut rng, l, k, 1.0);
    let landmark_vecs: Vec<Box<[f32]>> = (0..l)
        .map(|i| landmark_config.row(i).to_vec().into_boxed_slice())
        .collect();
    let lm = landmark_config.clone();
    let server: Server<[f32]> = Server::builder(
        landmark_vecs,
        Arc::new(Euclidean),
        factory_fn(move || {
            Box::new(RustOptimise {
                landmarks: lm.clone(),
                // generous budget: the landmark self-query check below
                // needs tight convergence, not the serving default
                cfg: lmds_ose::ose::OseOptConfig { max_iters: 3000, rel_tol: 1e-12 },
            })
        }),
    )
    .replicas(2)
    .build()
    .expect("valid server configuration");
    let h = server.handle();
    // query AT a landmark: the optimiser must map it near that landmark
    let target: Vec<f32> = landmark_config.row(5).to_vec();
    let r = h.submit(Request::object(target.clone())).recv().unwrap();
    assert_eq!(r.coords.len(), k);
    let err: f32 = r
        .coords
        .iter()
        .zip(landmark_config.row(5))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(err < 0.25, "landmark query mapped {err} away from itself");
    // and a batch of random vector queries all complete
    let tickets: Vec<_> = (0..20)
        .map(|i| {
            let q: Vec<f32> = (0..k).map(|c| (i + c) as f32 * 0.1).collect();
            h.submit(Request::object(q))
        })
        .collect();
    for t in tickets {
        assert!(t.recv().is_ok());
    }
    let snap = h.metrics.snapshot();
    assert_eq!(snap.completed, 21);
    assert_eq!(snap.failed, 0);
    drop(h);
    server.shutdown();
}
