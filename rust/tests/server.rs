//! Integration: the streaming OSE service over the backend-generic NN
//! method — requests flow frontend -> batcher -> compute backend and back.
//! Runs on the native backend unconditionally, so CI exercises the whole
//! serving path without artifacts.

use std::sync::Arc;
use std::time::Duration;

use lmds_ose::coordinator::methods::BackendNn;
use lmds_ose::coordinator::{BatcherConfig, Server};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::nn::{MlpParams, MlpShape};
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Levenshtein;
use lmds_ose::util::prng::Rng;

fn start_backend_server(backend: Backend, max_batch: usize) -> Server {
    let mut rng = Rng::new(31);
    let mut geco = Geco::new(GecoConfig { seed: 77, ..Default::default() });
    let landmarks = geco.generate_unique(32);
    let params = MlpParams::init(
        &MlpShape { input: 32, hidden: [32, 16, 8], output: 7 },
        &mut rng,
    );
    Server::start(
        landmarks,
        Arc::new(Levenshtein),
        Box::new(BackendNn::new(backend, params)),
        BatcherConfig {
            max_batch,
            max_delay: Duration::from_millis(2),
            queue_cap: 512,
            frontend_threads: 2,
        },
    )
}

#[test]
fn backend_service_serves_queries() {
    let server = start_backend_server(Backend::native(), 8);
    let sh = server.handle();
    let mut geco = Geco::new(GecoConfig { seed: 78, ..Default::default() });
    let rxs: Vec<_> = (0..100)
        .map(|_| sh.query(geco.sample_name()))
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.coords.len(), 7);
        assert!(r.coords.iter().all(|c| c.is_finite()));
    }
    let snap = sh.metrics.snapshot();
    assert_eq!(snap.completed, 100);
    assert_eq!(snap.failed, 0);
    drop(sh);
    server.shutdown();
}

#[test]
fn backend_service_batches_and_is_deterministic() {
    let server = start_backend_server(Backend::native(), 8);
    let sh = server.handle();
    // identical queries must give identical coordinates regardless of the
    // batch they landed in (batch composition must not leak)
    let rx1: Vec<_> = (0..16).map(|_| sh.query("anna smith".into())).collect();
    let first: Vec<Vec<f32>> = rx1
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap().coords)
        .collect();
    for c in &first {
        assert_eq!(c, &first[0]);
    }
    // and a lone straggler (batch of 1) agrees too
    std::thread::sleep(Duration::from_millis(10));
    let solo = sh.query_sync("anna smith").unwrap();
    let max_diff = solo
        .coords
        .iter()
        .zip(first[0].iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "batching leaked into results: {max_diff}");
    drop(sh);
    server.shutdown();
}

#[test]
fn service_single_query_latency_under_paper_bound() {
    // paper Sec. 6: NN maps a new point in < 1 ms. Measure the steady-state
    // single-query path (batcher delay excluded: use max_delay=0-ish).
    let mut rng = Rng::new(41);
    let mut geco = Geco::new(GecoConfig { seed: 79, ..Default::default() });
    let landmarks = geco.generate_unique(32);
    let params = MlpParams::init(
        &MlpShape { input: 32, hidden: [32, 16, 8], output: 7 },
        &mut rng,
    );
    let server = Server::start(
        landmarks,
        Arc::new(Levenshtein),
        Box::new(BackendNn::new(Backend::native(), params)),
        BatcherConfig {
            max_batch: 1,
            max_delay: Duration::from_micros(100),
            queue_cap: 64,
            frontend_threads: 1,
        },
    );
    let sh = server.handle();
    // warm caches and the thread pool
    for _ in 0..20 {
        sh.query_sync("warmup query").unwrap();
    }
    let mut lat = Vec::new();
    for i in 0..50 {
        let r = sh.query_sync(&format!("query {i}")).unwrap();
        lat.push(r.latency.as_secs_f64());
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat[lat.len() / 2];
    // generous CI bound; the bench harness reports the tight number
    assert!(p50 < 0.05, "p50 single-query latency {p50}s");
    drop(sh);
    server.shutdown();
}
