//! Integration: the sparse `query_k` OSE path and its landmark
//! small-world graph (docs/QUERY_PATH.md).
//!
//! Guardrails enforced here, end to end:
//! - graph k-nearest recall@k stays >= 0.95 against the brute-force scan
//!   at a realistic landmark scale (quality of the ANN structure);
//! - `query_k in {8, 32, L}` embeddings stay inside a sampled-stress band
//!   of the dense all-landmark solve (quality of the sparse objective);
//! - `query_k in {0, L}` are *bit-identical* to the dense path through
//!   the public replica factories (the "sparse off == exactly the old
//!   code" contract);
//! - a sharded server with `query_k` set keeps recovering realizable
//!   query positions through shard-local graphs and the quorum reduce.

use std::sync::Arc;
use std::time::Duration;

use lmds_ose::coordinator::methods::BackendOpt;
use lmds_ose::coordinator::{
    BatcherConfig, Request, Server, ServerBuilder, ShardConfig,
};
use lmds_ose::mds::graph::{nearest_k, GraphConfig, LandmarkGraph};
use lmds_ose::mds::Matrix;
use lmds_ose::ose::OseMethod;
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Euclidean;
use lmds_ose::util::prng::Rng;

/// Fixed majorization budget: deterministic work on every path.
const STEPS: usize = 1500;

/// Exact Euclidean delta row from a query point to every landmark row.
fn delta_to(config: &Matrix, q: &[f32]) -> Vec<f32> {
    (0..config.rows)
        .map(|i| {
            config
                .row(i)
                .iter()
                .zip(q)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt()
        })
        .collect()
}

/// Normalized residual stress of embeddings `y` against their full delta
/// rows: sqrt(sum (d_hat - delta)^2 / sum delta^2) over all Q x L pairs.
fn query_stress(config: &Matrix, deltas: &Matrix, y: &Matrix) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for r in 0..y.rows {
        let d_hat = delta_to(config, y.row(r));
        for (dh, d) in d_hat.iter().zip(deltas.row(r)) {
            num += (*dh as f64 - *d as f64).powi(2);
            den += (*d as f64).powi(2);
        }
    }
    (num / den).sqrt()
}

fn opt_method(
    config: &Matrix,
    query_k: usize,
    graph: Option<Arc<LandmarkGraph>>,
) -> BackendOpt {
    BackendOpt {
        backend: Backend::native(),
        landmarks: config.clone(),
        total_steps: STEPS,
        lr: None,
        rel_tol: 0.0,
        query_k,
        graph,
    }
}

#[test]
fn graph_knn_recall_at_k_is_high_at_scale() {
    const L: usize = 2000;
    const K: usize = 6;
    const TOP: usize = 10;
    let mut rng = Rng::new(0x9ec4);
    let config = Matrix::random_normal(&mut rng, L, K, 1.0);
    let graph = LandmarkGraph::build(&config, &GraphConfig::default());

    let mut hit = 0usize;
    let mut total = 0usize;
    for _ in 0..100 {
        let q: Vec<f32> = (0..K).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let delta = delta_to(&config, &q);
        let approx = graph.knn_delta(&delta, TOP);
        let exact = nearest_k(&delta, TOP);
        assert_eq!(approx.len(), TOP);
        // both sides come back sorted ascending: sorted intersection
        let (mut i, mut j) = (0, 0);
        while i < approx.len() && j < exact.len() {
            match approx[i].cmp(&exact[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    hit += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        total += TOP;
    }
    let recall = hit as f64 / total as f64;
    assert!(
        recall >= 0.95,
        "graph kNN recall@{TOP} = {recall} over 100 queries at L={L} \
         (want >= 0.95)"
    );
}

#[test]
fn sparse_query_k_stays_in_the_stress_band_of_dense() {
    const L: usize = 256;
    const K: usize = 3;
    let mut rng = Rng::new(0x51ab);
    let config = Matrix::random_normal(&mut rng, L, K, 1.0);
    let graph =
        Arc::new(LandmarkGraph::build(&config, &GraphConfig::default()));

    // realizable queries: points from the same cloud, so every restricted
    // solve is still solving for an exactly-representable position
    let queries: Vec<Vec<f32>> = (0..20)
        .map(|_| (0..K).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
        .collect();
    let mut rows = Vec::with_capacity(queries.len() * L);
    for q in &queries {
        rows.extend(delta_to(&config, q));
    }
    let deltas = Matrix::from_vec(queries.len(), L, rows);

    let y_dense = opt_method(&config, 0, None).embed(&deltas).unwrap();
    let stress_dense = query_stress(&config, &deltas, &y_dense);
    assert!(
        stress_dense < 0.05,
        "dense solve should nail realizable queries (stress {stress_dense})"
    );

    for k in [8usize, 32] {
        let y = opt_method(&config, k, Some(Arc::clone(&graph)))
            .embed(&deltas)
            .unwrap();
        assert!(y.data.iter().all(|v| v.is_finite()));
        let stress = query_stress(&config, &deltas, &y);
        // 5% relative band plus a small absolute floor: near-zero dense
        // stress must not turn the band into a zero-tolerance equality
        assert!(
            stress <= 1.05 * stress_dense + 0.02,
            "query_k={k}: sparse stress {stress} outside the band of \
             dense {stress_dense}"
        );
    }

    // query_k = L short-circuits to the dense code path: bit-equal
    let y_full = opt_method(&config, L, None).embed(&deltas).unwrap();
    assert_eq!(y_full.data, y_dense.data, "query_k=L must be bit-identical");
}

#[test]
fn sparse_factories_at_query_k_zero_and_l_are_bit_identical_to_dense() {
    const L: usize = 64;
    const K: usize = 3;
    let mut rng = Rng::new(0x7d0c);
    let config = Matrix::random_normal(&mut rng, L, K, 1.0);
    let queries: Vec<Vec<f32>> = (0..6)
        .map(|_| (0..K).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
        .collect();
    let mut rows = Vec::with_capacity(queries.len() * L);
    for q in &queries {
        rows.extend(delta_to(&config, q));
    }
    let deltas = Matrix::from_vec(queries.len(), L, rows);
    let gcfg = GraphConfig::default();

    let dense =
        BackendOpt::replica_factory_budget(Backend::native(), config.clone(), STEPS);
    let want = dense.build().embed(&deltas).unwrap();

    for query_k in [0usize, L, L + 7] {
        let sparse = BackendOpt::replica_factory_sparse(
            Backend::native(),
            config.clone(),
            STEPS,
            query_k,
            &gcfg,
        );
        let got = sparse.build().embed(&deltas).unwrap();
        assert_eq!(
            got.data, want.data,
            "query_k={query_k} must take the dense path bit-identically"
        );
    }
}

#[test]
fn sharded_serving_with_query_k_recovers_realizable_queries() {
    const L: usize = 48;
    const K: usize = 3;
    let mut rng = Rng::new(0x5a4d);
    let config = Matrix::random_normal(&mut rng, L, K, 1.0);
    let vecs: Vec<Box<[f32]>> = (0..L)
        .map(|i| config.row(i).to_vec().into_boxed_slice())
        .collect();

    let builder: ServerBuilder<[f32]> = Server::builder(
        vecs,
        Arc::new(Euclidean),
        BackendOpt::replica_factory_budget(Backend::native(), config.clone(), STEPS),
    )
    .landmark_config(config.clone())
    .batcher(BatcherConfig {
        max_batch: 8,
        max_delay: Duration::from_millis(1),
        queue_cap: 256,
        frontend_threads: 2,
        replicas: 1,
    })
    .shards(ShardConfig {
        shards: 2,
        anchors: 12,
        opt_steps: STEPS,
        query_k: 8,
        graph: GraphConfig::default(),
        ..Default::default()
    });
    let server = builder.build_sharded().expect("valid sharded configuration");
    let h = server.handle();

    let mut rng = Rng::new(0xbead);
    for _ in 0..8 {
        let q: Vec<f32> = (0..K).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let r = h
            .submit(Request::delta(delta_to(&config, q.as_slice())))
            .recv()
            .expect("sharded sparse query");
        assert!(!r.degraded, "all shards healthy: no degradation");
        assert!(r.coords.iter().all(|v| v.is_finite()));
        // each shard solves q from its 8 nearest slice landmarks (exact
        // distances, realizable point), so the quorum mean recovers q up
        // to the usual partition band
        let err = r
            .coords
            .iter()
            .zip(&q)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            err < 0.3,
            "sparse sharded embedding {err} off the true query position"
        );
    }
    drop(h);
    server.shutdown();
}
