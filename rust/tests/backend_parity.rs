//! Cross-checks of the native compute backend against the serial pure-Rust
//! oracles — the correctness contract of the backend seam (and, with
//! `--features pjrt` + real artifacts, the same contract the PJRT backend
//! is held to):
//!
//! - `ose_opt_steps` vs `ose::optimise::embed_point` (same fixed step
//!   budget): coordinates and Eq.-2 objective within 1e-5 relative.
//! - `mlp_fwd` vs `nn::forward`: within 1e-5.
//! - the blocked production kernels (`stress_gradient_blocked`,
//!   `forward_block`) vs their serial oracles on random shapes, including
//!   k=1, single-row and non-multiple-of-tile sizes.
//! - `lsmds_steps` vs an explicit `stress_gradient_blocked` descent loop
//!   (the stepping/chunking logic, same kernel).
//! - `mlp_train_step` sequences vs `nn::Adam` over structured state.
//! - `train_backend` (native) vs `train_rust`: identical trajectories.

use lmds_ose::coordinator::trainer::{train_backend, train_rust, TrainConfig};
use lmds_ose::mds::lsmds::{stress_gradient, stress_gradient_blocked, GRAD_TILE};
use lmds_ose::mds::Matrix;
use lmds_ose::nn::{self, MlpParams, MlpShape};
use lmds_ose::ose::optimise::{embed_point, objective_and_grad, OseOptConfig};
use lmds_ose::runtime::{AdamState, Backend, ComputeBackend, NativeBackend};
use lmds_ose::strdist::euclidean;
use lmds_ose::util::prng::Rng;

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn ose_opt_steps_matches_embed_point_oracle() {
    let mut rng = Rng::new(0xA);
    for &(l, k, b, steps) in &[(32usize, 7usize, 8usize, 5usize), (50, 3, 17, 60)] {
        let lm = Matrix::random_normal(&mut rng, l, k, 1.0);
        let deltas = Matrix::from_vec(
            b,
            l,
            (0..b * l).map(|_| rng.next_f32() * 3.0 + 0.5).collect(),
        );
        let y0 = Matrix::zeros(b, k);
        let lr = (1.0 / (2.0 * l as f64)) as f32;
        let (y, obj) = NativeBackend
            .ose_opt_steps(&lm, &deltas, &y0, lr, steps)
            .unwrap();
        assert_eq!((y.rows, y.cols), (b, k));
        assert_eq!(obj.len(), b);

        // oracle: the serial per-point optimiser, early stopping disabled,
        // driven for exactly the same number of majorization steps
        for r in 0..b {
            let p = embed_point(
                &lm,
                deltas.row(r),
                None,
                &OseOptConfig { max_iters: steps, rel_tol: -1.0 },
            );
            let coord_diff = max_abs_diff(y.row(r), &p.coords);
            assert!(
                coord_diff < 1e-5,
                "L={l} B={b} row {r}: coords diverge by {coord_diff}"
            );
            // acceptance: objective within 1e-5 relative of the oracle's
            let (oracle_obj, _) = objective_and_grad(&lm, deltas.row(r), &p.coords);
            let rel = (obj[r] as f64 - oracle_obj).abs() / oracle_obj.max(1e-30);
            assert!(
                rel < 1e-5,
                "L={l} B={b} row {r}: objective {} vs oracle {oracle_obj} (rel {rel})",
                obj[r]
            );
        }
    }
}

#[test]
fn ose_opt_steps_warm_start_composes() {
    // 2 x 30 steps from the chunked path == 60 straight steps
    let mut rng = Rng::new(0xB);
    let lm = Matrix::random_normal(&mut rng, 20, 4, 1.0);
    let deltas = Matrix::from_vec(
        6,
        20,
        (0..120).map(|_| rng.next_f32() * 2.0 + 0.5).collect(),
    );
    let y0 = Matrix::zeros(6, 4);
    let lr = 1.0 / 40.0;
    let (full, _) = NativeBackend.ose_opt_steps(&lm, &deltas, &y0, lr, 60).unwrap();
    let (half, _) = NativeBackend.ose_opt_steps(&lm, &deltas, &y0, lr, 30).unwrap();
    let (resumed, _) = NativeBackend.ose_opt_steps(&lm, &deltas, &half, lr, 30).unwrap();
    assert!(
        full.max_abs_diff(&resumed) < 1e-6,
        "chunked warm start diverges: {}",
        full.max_abs_diff(&resumed)
    );
}

#[test]
fn mlp_fwd_matches_oracle_forward() {
    let mut rng = Rng::new(0xC);
    for &(l, hidden, k, b) in &[
        (32usize, [32usize, 16, 8], 7usize, 8usize),
        (12, [16, 16, 8], 3, 33),
    ] {
        let params = MlpParams::init(
            &MlpShape { input: l, hidden, output: k },
            &mut rng,
        );
        let d = Matrix::from_vec(
            b,
            l,
            (0..b * l).map(|_| rng.next_f32() * 4.0).collect(),
        );
        let y_backend = NativeBackend.mlp_fwd(&params, &d).unwrap();
        let y_oracle = nn::forward(&params, &d);
        let diff = y_backend.max_abs_diff(&y_oracle);
        // acceptance: MLP forward within 1e-5 of the oracle
        assert!(diff < 1e-5, "L={l} B={b}: forward diverges by {diff}");
    }
}

#[test]
fn mlp_loss_matches_oracle_loss() {
    let mut rng = Rng::new(0xD);
    let params = MlpParams::init(
        &MlpShape { input: 16, hidden: [16, 8, 8], output: 3 },
        &mut rng,
    );
    let d = Matrix::from_vec(10, 16, (0..160).map(|_| rng.next_f32() * 3.0).collect());
    let x = Matrix::random_normal(&mut rng, 10, 3, 1.0);
    let got = NativeBackend.mlp_loss(&params, &d, &x).unwrap();
    let want = nn::mae_loss(&nn::forward(&params, &d), &x);
    assert!(
        (got - want).abs() < 1e-6 * (1.0 + want),
        "loss {got} vs oracle {want}"
    );
}

#[test]
fn stress_gradient_blocked_matches_serial_oracle() {
    // shapes chosen to hit every edge of the tiling: k = 1, a single row
    // (no j != i terms: zero gradient), n below / at / just past the tile
    // width, and a large non-multiple-of-tile n
    let mut rng = Rng::new(0x11);
    let shapes: &[(usize, usize)] = &[
        (1, 1),
        (1, 3),
        (2, 1),
        (7, 1),
        (33, 4),
        (GRAD_TILE, 2),
        (GRAD_TILE + 1, 3),
        (200, 7),
    ];
    for &(n, k) in shapes {
        let x = Matrix::random_normal(&mut rng, n, k, 1.0);
        // non-realizable symmetric deltas with zero diagonal, so residuals
        // are O(1) everywhere and the gradient has real magnitude
        let mut delta = Matrix::zeros(n, n);
        for i in 0..n {
            for j in (i + 1)..n {
                let d = rng.next_f32() * 4.0 + 0.1;
                delta.set(i, j, d);
                delta.set(j, i, d);
            }
        }
        let (gs, ss) = stress_gradient(&x, &delta);
        let (gb, sb) = stress_gradient_blocked(&x, &delta);
        assert_eq!((gb.rows, gb.cols), (n, k));
        let gmax = gs.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let diff = gs.max_abs_diff(&gb);
        // scale-aware: the blocked kernel accumulates the gradient in f32
        assert!(
            diff < 1e-3 * (1.0 + gmax),
            "n={n} k={k}: grad diverges by {diff} (scale {gmax})"
        );
        assert!(
            (ss - sb).abs() < 1e-5 * (1.0 + ss),
            "n={n} k={k}: sigma {ss} vs {sb}"
        );
        if n == 1 {
            assert!(gb.data.iter().all(|v| *v == 0.0), "single row => zero grad");
            assert_eq!(sb, 0.0);
        }
    }
}

#[test]
fn forward_block_matches_oracle_forward() {
    // random shapes including B=1, L=1, K=1 and non-multiple-of-block sizes
    let mut rng = Rng::new(0x12);
    let shapes: &[(usize, [usize; 3], usize, usize)] = &[
        (1, [4, 4, 4], 1, 1),
        (5, [8, 8, 8], 3, 1),
        (12, [16, 8, 8], 2, 7),
        (32, [32, 16, 8], 7, 33),
        (300, [64, 32, 16], 7, 50),
    ];
    for &(l, hidden, k, b) in shapes {
        let params = MlpParams::init(&MlpShape { input: l, hidden, output: k }, &mut rng);
        let d = Matrix::from_vec(
            b,
            l,
            (0..b * l).map(|_| rng.next_f32() * 4.0).collect(),
        );
        let oracle = nn::forward(&params, &d);
        let blocked = nn::forward_blocked(&params, &d);
        let diff = oracle.max_abs_diff(&blocked);
        assert!(diff < 1e-6, "L={l} B={b}: blocked forward diverges by {diff}");
        // and the backend path (parallel over row blocks) agrees too
        let via_backend = NativeBackend.mlp_fwd(&params, &d).unwrap();
        let diff = oracle.max_abs_diff(&via_backend);
        assert!(diff < 1e-6, "L={l} B={b}: backend forward diverges by {diff}");
    }
}

#[test]
fn lsmds_steps_matches_explicit_gradient_descent() {
    let n = 24;
    let k = 3;
    let mut rng = Rng::new(0xE);
    let hidden = Matrix::random_normal(&mut rng, n, k, 1.0);
    let mut delta = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            delta.set(i, j, euclidean(hidden.row(i), hidden.row(j)) as f32);
        }
    }
    let mut x0 = Matrix::random_normal(&mut rng, n, k, 1.0);
    x0.center_columns();
    let lr = (1.0 / (2.0 * n as f64)) as f32;
    let steps = 7;

    let (x_backend, sigma_backend) =
        NativeBackend.lsmds_steps(&x0, &delta, lr, steps).unwrap();

    // oracle loop runs the same blocked kernel the backend uses: this test
    // pins the stepping logic (update rule, sigma reporting), while the
    // kernel itself is held to the serial oracle by
    // stress_gradient_blocked_matches_serial_oracle above
    let mut x = x0.clone();
    let mut sigma = f64::NAN;
    for _ in 0..steps {
        let (grad, s) = stress_gradient_blocked(&x, &delta);
        sigma = s;
        for (xi, gi) in x.data.iter_mut().zip(grad.data.iter()) {
            *xi -= (lr as f64 * *gi as f64) as f32;
        }
    }
    assert!(
        x_backend.max_abs_diff(&x) < 1e-6,
        "configs diverge: {}",
        x_backend.max_abs_diff(&x)
    );
    assert!(
        (sigma_backend - sigma).abs() < 1e-9 * (1.0 + sigma),
        "sigma {sigma_backend} vs {sigma}"
    );
}

#[test]
fn mlp_train_step_matches_structured_adam() {
    let mut rng = Rng::new(0xF);
    let shape = MlpShape { input: 10, hidden: [8, 8, 8], output: 3 };
    let init = MlpParams::init(&shape, &mut rng);
    let lr = 1e-3f32;

    // backend path: flat AdamState
    let mut state = AdamState::new(&init);
    // oracle path: structured params + nn::Adam
    let mut params = init.clone();
    let mut adam = nn::Adam::new(&shape, lr);

    for step in 0..5 {
        let d = Matrix::from_vec(
            6,
            10,
            (0..60).map(|_| rng.next_f32() * 3.0).collect(),
        );
        let x = Matrix::random_normal(&mut rng, 6, 3, 1.0);
        let loss_backend =
            NativeBackend.mlp_train_step(&mut state, &d, &x, lr).unwrap() as f64;
        let (loss_oracle, grads) = nn::backward(&params, &d, &x);
        adam.step(&mut params, &grads);
        assert!(
            (loss_backend - loss_oracle).abs() < 1e-6 * (1.0 + loss_oracle),
            "step {step}: loss {loss_backend} vs {loss_oracle}"
        );
        let trained = state.to_params();
        for layer in 0..4 {
            assert!(
                trained.w[layer].max_abs_diff(&params.w[layer]) < 1e-6,
                "step {step}: weights diverge at layer {layer}"
            );
            assert!(
                max_abs_diff(&trained.b[layer], &params.b[layer]) < 1e-6,
                "step {step}: biases diverge at layer {layer}"
            );
        }
    }
    assert_eq!(state.t, 5.0);
}

#[test]
fn train_backend_native_matches_train_rust() {
    let mut rng = Rng::new(0x10);
    let shape = MlpShape { input: 9, hidden: [12, 8, 8], output: 2 };
    let inputs = Matrix::from_vec(
        50,
        9,
        (0..450).map(|_| rng.next_f32() * 2.0).collect(),
    );
    let labels = Matrix::random_normal(&mut rng, 50, 2, 1.0);
    // no early stopping: both paths must run the same number of steps
    let cfg = TrainConfig { epochs: 6, patience: 1000, seed: 99, ..Default::default() };
    let backend = Backend::native();
    let (p_backend, r_backend) =
        train_backend(&backend, &shape, &inputs, &labels, 16, &cfg).unwrap();
    let (p_rust, r_rust) = train_rust(&shape, &inputs, &labels, 16, &cfg);
    assert_eq!(r_backend.epochs_run, r_rust.epochs_run);
    for layer in 0..4 {
        assert!(
            p_backend.w[layer].max_abs_diff(&p_rust.w[layer]) < 1e-6,
            "layer {layer} weights diverge"
        );
    }
    let last_b = *r_backend.loss_history.last().unwrap();
    let last_r = *r_rust.loss_history.last().unwrap();
    assert!(
        (last_b - last_r).abs() < 1e-5 * (1.0 + last_r),
        "loss history diverges: {last_b} vs {last_r}"
    );
}
