//! Cross-checks of the native compute backend against the serial pure-Rust
//! oracles — the correctness contract of the backend seam (and, with
//! `--features pjrt` + real artifacts, the same contract the PJRT backend
//! is held to):
//!
//! - `ose_opt_steps` vs `ose::optimise::embed_point` (same fixed step
//!   budget): coordinates and Eq.-2 objective within 1e-5 relative.
//! - `mlp_fwd` vs `nn::forward`: within 1e-5.
//! - `lsmds_steps` vs an explicit `stress_gradient` descent loop.
//! - `mlp_train_step` sequences vs `nn::Adam` over structured state.
//! - `train_backend` (native) vs `train_rust`: identical trajectories.

use lmds_ose::coordinator::trainer::{train_backend, train_rust, TrainConfig};
use lmds_ose::mds::lsmds::stress_gradient;
use lmds_ose::mds::Matrix;
use lmds_ose::nn::{self, MlpParams, MlpShape};
use lmds_ose::ose::optimise::{embed_point, objective_and_grad, OseOptConfig};
use lmds_ose::runtime::{AdamState, Backend, ComputeBackend, NativeBackend};
use lmds_ose::strdist::euclidean;
use lmds_ose::util::prng::Rng;

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn ose_opt_steps_matches_embed_point_oracle() {
    let mut rng = Rng::new(0xA);
    for &(l, k, b, steps) in &[(32usize, 7usize, 8usize, 5usize), (50, 3, 17, 60)] {
        let lm = Matrix::random_normal(&mut rng, l, k, 1.0);
        let deltas = Matrix::from_vec(
            b,
            l,
            (0..b * l).map(|_| rng.next_f32() * 3.0 + 0.5).collect(),
        );
        let y0 = Matrix::zeros(b, k);
        let lr = (1.0 / (2.0 * l as f64)) as f32;
        let (y, obj) = NativeBackend
            .ose_opt_steps(&lm, &deltas, &y0, lr, steps)
            .unwrap();
        assert_eq!((y.rows, y.cols), (b, k));
        assert_eq!(obj.len(), b);

        // oracle: the serial per-point optimiser, early stopping disabled,
        // driven for exactly the same number of majorization steps
        for r in 0..b {
            let p = embed_point(
                &lm,
                deltas.row(r),
                None,
                &OseOptConfig { max_iters: steps, rel_tol: -1.0 },
            );
            let coord_diff = max_abs_diff(y.row(r), &p.coords);
            assert!(
                coord_diff < 1e-5,
                "L={l} B={b} row {r}: coords diverge by {coord_diff}"
            );
            // acceptance: objective within 1e-5 relative of the oracle's
            let (oracle_obj, _) = objective_and_grad(&lm, deltas.row(r), &p.coords);
            let rel = (obj[r] as f64 - oracle_obj).abs() / oracle_obj.max(1e-30);
            assert!(
                rel < 1e-5,
                "L={l} B={b} row {r}: objective {} vs oracle {oracle_obj} (rel {rel})",
                obj[r]
            );
        }
    }
}

#[test]
fn ose_opt_steps_warm_start_composes() {
    // 2 x 30 steps from the chunked path == 60 straight steps
    let mut rng = Rng::new(0xB);
    let lm = Matrix::random_normal(&mut rng, 20, 4, 1.0);
    let deltas = Matrix::from_vec(
        6,
        20,
        (0..120).map(|_| rng.next_f32() * 2.0 + 0.5).collect(),
    );
    let y0 = Matrix::zeros(6, 4);
    let lr = 1.0 / 40.0;
    let (full, _) = NativeBackend.ose_opt_steps(&lm, &deltas, &y0, lr, 60).unwrap();
    let (half, _) = NativeBackend.ose_opt_steps(&lm, &deltas, &y0, lr, 30).unwrap();
    let (resumed, _) = NativeBackend.ose_opt_steps(&lm, &deltas, &half, lr, 30).unwrap();
    assert!(
        full.max_abs_diff(&resumed) < 1e-6,
        "chunked warm start diverges: {}",
        full.max_abs_diff(&resumed)
    );
}

#[test]
fn mlp_fwd_matches_oracle_forward() {
    let mut rng = Rng::new(0xC);
    for &(l, hidden, k, b) in &[
        (32usize, [32usize, 16, 8], 7usize, 8usize),
        (12, [16, 16, 8], 3, 33),
    ] {
        let params = MlpParams::init(
            &MlpShape { input: l, hidden, output: k },
            &mut rng,
        );
        let d = Matrix::from_vec(
            b,
            l,
            (0..b * l).map(|_| rng.next_f32() * 4.0).collect(),
        );
        let y_backend = NativeBackend.mlp_fwd(&params, &d).unwrap();
        let y_oracle = nn::forward(&params, &d);
        let diff = y_backend.max_abs_diff(&y_oracle);
        // acceptance: MLP forward within 1e-5 of the oracle
        assert!(diff < 1e-5, "L={l} B={b}: forward diverges by {diff}");
    }
}

#[test]
fn mlp_loss_matches_oracle_loss() {
    let mut rng = Rng::new(0xD);
    let params = MlpParams::init(
        &MlpShape { input: 16, hidden: [16, 8, 8], output: 3 },
        &mut rng,
    );
    let d = Matrix::from_vec(10, 16, (0..160).map(|_| rng.next_f32() * 3.0).collect());
    let x = Matrix::random_normal(&mut rng, 10, 3, 1.0);
    let got = NativeBackend.mlp_loss(&params, &d, &x).unwrap();
    let want = nn::mae_loss(&nn::forward(&params, &d), &x);
    assert!(
        (got - want).abs() < 1e-6 * (1.0 + want),
        "loss {got} vs oracle {want}"
    );
}

#[test]
fn lsmds_steps_matches_explicit_gradient_descent() {
    let n = 24;
    let k = 3;
    let mut rng = Rng::new(0xE);
    let hidden = Matrix::random_normal(&mut rng, n, k, 1.0);
    let mut delta = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            delta.set(i, j, euclidean(hidden.row(i), hidden.row(j)) as f32);
        }
    }
    let mut x0 = Matrix::random_normal(&mut rng, n, k, 1.0);
    x0.center_columns();
    let lr = (1.0 / (2.0 * n as f64)) as f32;
    let steps = 7;

    let (x_backend, sigma_backend) =
        NativeBackend.lsmds_steps(&x0, &delta, lr, steps).unwrap();

    let mut x = x0.clone();
    let mut sigma = f64::NAN;
    for _ in 0..steps {
        let (grad, s) = stress_gradient(&x, &delta);
        sigma = s;
        for (xi, gi) in x.data.iter_mut().zip(grad.data.iter()) {
            *xi -= (lr as f64 * *gi as f64) as f32;
        }
    }
    assert!(
        x_backend.max_abs_diff(&x) < 1e-6,
        "configs diverge: {}",
        x_backend.max_abs_diff(&x)
    );
    assert!(
        (sigma_backend - sigma).abs() < 1e-9 * (1.0 + sigma),
        "sigma {sigma_backend} vs {sigma}"
    );
}

#[test]
fn mlp_train_step_matches_structured_adam() {
    let mut rng = Rng::new(0xF);
    let shape = MlpShape { input: 10, hidden: [8, 8, 8], output: 3 };
    let init = MlpParams::init(&shape, &mut rng);
    let lr = 1e-3f32;

    // backend path: flat AdamState
    let mut state = AdamState::new(&init);
    // oracle path: structured params + nn::Adam
    let mut params = init.clone();
    let mut adam = nn::Adam::new(&shape, lr);

    for step in 0..5 {
        let d = Matrix::from_vec(
            6,
            10,
            (0..60).map(|_| rng.next_f32() * 3.0).collect(),
        );
        let x = Matrix::random_normal(&mut rng, 6, 3, 1.0);
        let loss_backend =
            NativeBackend.mlp_train_step(&mut state, &d, &x, lr).unwrap() as f64;
        let (loss_oracle, grads) = nn::backward(&params, &d, &x);
        adam.step(&mut params, &grads);
        assert!(
            (loss_backend - loss_oracle).abs() < 1e-6 * (1.0 + loss_oracle),
            "step {step}: loss {loss_backend} vs {loss_oracle}"
        );
        let trained = state.to_params();
        for layer in 0..4 {
            assert!(
                trained.w[layer].max_abs_diff(&params.w[layer]) < 1e-6,
                "step {step}: weights diverge at layer {layer}"
            );
            assert!(
                max_abs_diff(&trained.b[layer], &params.b[layer]) < 1e-6,
                "step {step}: biases diverge at layer {layer}"
            );
        }
    }
    assert_eq!(state.t, 5.0);
}

#[test]
fn train_backend_native_matches_train_rust() {
    let mut rng = Rng::new(0x10);
    let shape = MlpShape { input: 9, hidden: [12, 8, 8], output: 2 };
    let inputs = Matrix::from_vec(
        50,
        9,
        (0..450).map(|_| rng.next_f32() * 2.0).collect(),
    );
    let labels = Matrix::random_normal(&mut rng, 50, 2, 1.0);
    // no early stopping: both paths must run the same number of steps
    let cfg = TrainConfig { epochs: 6, patience: 1000, seed: 99, ..Default::default() };
    let backend = Backend::native();
    let (p_backend, r_backend) =
        train_backend(&backend, &shape, &inputs, &labels, 16, &cfg).unwrap();
    let (p_rust, r_rust) = train_rust(&shape, &inputs, &labels, 16, &cfg);
    assert_eq!(r_backend.epochs_run, r_rust.epochs_run);
    for layer in 0..4 {
        assert!(
            p_backend.w[layer].max_abs_diff(&p_rust.w[layer]) < 1e-6,
            "layer {layer} weights diverge"
        );
    }
    let last_b = *r_backend.loss_history.last().unwrap();
    let last_r = *r_rust.loss_history.last().unwrap();
    assert!(
        (last_b - last_r).abs() < 1e-5 * (1.0 + last_r),
        "loss history diverges: {last_b} vs {last_r}"
    );
}
