//! Differential kernel-parity suite for the explicit SIMD tier: every
//! vector kernel against its serial oracle and its scalar twin, over
//! adversarial shapes.
//!
//! Tolerance policy (documented in docs/ARCHITECTURE.md):
//!
//! - **vector tier vs scalar tier: bit-exact (0 ULP).** Both implement
//!   the same canonical 8-lane tile reduction with no FMA, so equality
//!   is by construction — asserted with `to_bits()` everywhere,
//!   including remainder lanes `len % 8 ∈ {0..7}`, empty/single-row
//!   inputs, unaligned sub-slices, denormals and NaN/±inf (NaN compared
//!   by NaN-ness, not payload).
//! - **canonical order vs historical serial order: 1e-6 relative** on
//!   non-cancelling inputs (pure f64 rounding differences from
//!   regrouping), and scale-aware 1e-3 for the f32 stress-gradient
//!   tile against the f64 serial oracle (the band `backend_parity.rs`
//!   has always used).
//!
//! On machines without a vector tier (and under Miri) the `_vector`
//! twins fall back to scalar and the bit-equality asserts hold
//! trivially; CI's x86_64 runners exercise the AVX2 tier for real.

use std::sync::Mutex;

use lmds_ose::mds::lsmds::{stress_gradient, stress_gradient_blocked};
use lmds_ose::mds::Matrix;
use lmds_ose::nn::{forward, forward_blocked, MlpParams, MlpShape};
use lmds_ose::runtime::simd::{
    affine_into_scalar, affine_into_vector, euclidean_sq_scalar, euclidean_sq_vector,
    manhattan_scalar, manhattan_vector, set_kernel_tier, simd_supported,
    stress_row_tile_scalar, stress_row_tile_vector, KernelTier,
};
use lmds_ose::util::prng::Rng;

/// End-to-end tests that flip the process-wide tier hold this lock so
/// their scalar and simd runs cannot interleave with each other. (The
/// tier-pinned `_scalar`/`_vector` twins used everywhere else never
/// touch global state.)
static TIER_LOCK: Mutex<()> = Mutex::new(());

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect()
}

/// Every remainder class twice, plus empty/single and multi-tile sizes.
const LENS: [usize; 16] = [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 24, 40, 65];

#[test]
fn metric_vector_matches_scalar_bit_for_bit() {
    let mut rng = Rng::new(0xA11);
    for &n in &LENS {
        let a = rand_vec(&mut rng, n, 2.0);
        let b = rand_vec(&mut rng, n, 2.0);
        let (s, v) = (euclidean_sq_scalar(&a, &b), euclidean_sq_vector(&a, &b));
        assert_eq!(s.to_bits(), v.to_bits(), "euclidean_sq n={n}");
        let (s, v) = (manhattan_scalar(&a, &b), manhattan_vector(&a, &b));
        assert_eq!(s.to_bits(), v.to_bits(), "manhattan n={n}");
    }
}

#[test]
fn metric_unaligned_subslices_match() {
    // Sub-slices of Matrix rows are the production shape (row k = 7 puts
    // successive rows at every 4-byte alignment); offset slices of a
    // shared buffer push it further.
    let mut rng = Rng::new(0xA12);
    let buf = rand_vec(&mut rng, 200, 2.0);
    for off in 0..8 {
        for &n in &[7usize, 16, 33] {
            let a = &buf[off..off + n];
            let b = &buf[off + 71..off + 71 + n];
            assert_eq!(
                euclidean_sq_scalar(a, b).to_bits(),
                euclidean_sq_vector(a, b).to_bits(),
                "off={off} n={n}"
            );
        }
    }
    let x = Matrix::from_vec(6, 7, rand_vec(&mut rng, 42, 2.0));
    for i in 0..6 {
        for j in 0..6 {
            assert_eq!(
                euclidean_sq_scalar(x.row(i), x.row(j)).to_bits(),
                euclidean_sq_vector(x.row(i), x.row(j)).to_bits()
            );
            assert_eq!(
                manhattan_scalar(x.row(i), x.row(j)).to_bits(),
                manhattan_vector(x.row(i), x.row(j)).to_bits()
            );
        }
    }
}

#[test]
fn metric_denormals_nan_inf_propagate_identically() {
    // denormal f32 inputs: squares land around 1e-84, comfortably inside
    // f64 range — both tiers must agree exactly
    let tiny = vec![1.0e-42f32; 19];
    let zero = vec![0.0f32; 19];
    let s = euclidean_sq_scalar(&tiny, &zero);
    assert!(s > 0.0, "denormal differences must not flush to zero in f64");
    assert_eq!(s.to_bits(), euclidean_sq_vector(&tiny, &zero).to_bits());
    assert_eq!(
        manhattan_scalar(&tiny, &zero).to_bits(),
        manhattan_vector(&tiny, &zero).to_bits()
    );

    // NaN in any lane position poisons the result on every tier
    for pos in [0usize, 3, 8, 12] {
        let mut a = vec![1.0f32; 13];
        a[pos] = f32::NAN;
        let b = vec![0.5f32; 13];
        assert!(euclidean_sq_scalar(&a, &b).is_nan(), "pos={pos}");
        assert!(euclidean_sq_vector(&a, &b).is_nan(), "pos={pos}");
        assert!(manhattan_scalar(&a, &b).is_nan(), "pos={pos}");
        assert!(manhattan_vector(&a, &b).is_nan(), "pos={pos}");
    }

    // ±inf: squares/abs give +inf, identical bits on every tier
    for inf in [f32::INFINITY, f32::NEG_INFINITY] {
        let mut a = vec![1.0f32; 11];
        a[9] = inf;
        let b = vec![-2.0f32; 11];
        let s = euclidean_sq_scalar(&a, &b);
        assert_eq!(s, f64::INFINITY);
        assert_eq!(s.to_bits(), euclidean_sq_vector(&a, &b).to_bits());
        let s = manhattan_scalar(&a, &b);
        assert_eq!(s, f64::INFINITY);
        assert_eq!(s.to_bits(), manhattan_vector(&a, &b).to_bits());
    }
}

#[test]
fn metric_canonical_tracks_serial_oracle_band() {
    let mut rng = Rng::new(0xA13);
    for &n in &LENS {
        let a = rand_vec(&mut rng, n, 5.0);
        let b = rand_vec(&mut rng, n, 5.0);
        let serial: f64 = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| {
                let d = (*x - *y) as f64;
                d * d
            })
            .sum();
        let got = euclidean_sq_vector(&a, &b);
        assert!(
            (got - serial).abs() <= 1e-6 * (1.0 + serial.abs()),
            "n={n}: {got} vs serial {serial}"
        );
    }
}

#[test]
fn stress_tile_vector_matches_scalar_bit_for_bit() {
    let mut rng = Rng::new(0xA14);
    let n = 23;
    for k in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 16, 17] {
        let x = Matrix::from_vec(n, k, rand_vec(&mut rng, n * k, 1.0));
        let delta = Matrix::from_vec(n, n, rand_vec(&mut rng, n * n, 1.0));
        for i in [0usize, 7, 22] {
            // start both tiers from the same nonzero gradient so the
            // accumulate-into contract is covered too
            let g0 = rand_vec(&mut rng, k, 0.5);
            let mut gs = g0.clone();
            let mut gv = g0.clone();
            let mut ds = vec![0.0f32; k];
            let mut dv = vec![0.0f32; k];
            let ss = stress_row_tile_scalar(
                x.row(i),
                &x,
                0,
                n,
                i,
                delta.row(i),
                &mut gs,
                &mut ds,
            );
            let sv = stress_row_tile_vector(
                x.row(i),
                &x,
                0,
                n,
                i,
                delta.row(i),
                &mut gv,
                &mut dv,
            );
            assert_eq!(ss.to_bits(), sv.to_bits(), "stress k={k} i={i}");
            for c in 0..k {
                assert_eq!(gs[c].to_bits(), gv[c].to_bits(), "grad k={k} i={i} c={c}");
                assert_eq!(ds[c].to_bits(), dv[c].to_bits(), "diff k={k} i={i} c={c}");
            }
        }
    }
}

#[test]
fn stress_tile_empty_and_degenerate_tiles() {
    let mut rng = Rng::new(0xA15);
    let k = 7;
    let x = Matrix::from_vec(4, k, rand_vec(&mut rng, 4 * k, 1.0));
    let delta = Matrix::from_vec(4, 4, rand_vec(&mut rng, 16, 1.0));
    let g0 = rand_vec(&mut rng, k, 0.5);

    // empty tile: zero stress, gradient untouched
    for f in [stress_row_tile_scalar, stress_row_tile_vector] {
        let mut g = g0.clone();
        let mut d = vec![0.0f32; k];
        let s = f(x.row(0), &x, 2, 2, 0, delta.row(0), &mut g, &mut d);
        assert_eq!(s, 0.0);
        assert_eq!(g, g0);
    }

    // single-row tile that is the skipped row itself: also a no-op
    for f in [stress_row_tile_scalar, stress_row_tile_vector] {
        let mut g = g0.clone();
        let mut d = vec![0.0f32; k];
        let s = f(x.row(1), &x, 1, 2, 1, delta.row(1), &mut g, &mut d);
        assert_eq!(s, 0.0);
        assert_eq!(g, g0);
    }

    // coincident rows (d == 0): stress counts the residual, gradient
    // guard leaves g untouched — identically on both tiers
    let mut xx = x.clone();
    xx.row_mut(2).copy_from_slice(x.row(3));
    let mut results = Vec::new();
    for f in [stress_row_tile_scalar, stress_row_tile_vector] {
        let mut g = g0.clone();
        let mut d = vec![0.0f32; k];
        let s = f(xx.row(2), &xx, 3, 4, 2, delta.row(2), &mut g, &mut d);
        assert_eq!(g, g0, "zero distance must not touch the gradient");
        results.push(s);
    }
    assert_eq!(results[0].to_bits(), results[1].to_bits());
}

#[test]
fn affine_vector_matches_scalar_bit_for_bit() {
    let mut rng = Rng::new(0xA16);
    for &(n_in, n_out) in &[
        (1usize, 1usize),
        (1, 7),
        (3, 8),
        (7, 9),
        (8, 16),
        (5, 17),
        (300, 33),
        (0, 5), // empty input: out == bias
    ] {
        let w = Matrix::from_vec(n_in, n_out, rand_vec(&mut rng, n_in * n_out, 1.0));
        let b = rand_vec(&mut rng, n_out, 1.0);
        let x = rand_vec(&mut rng, n_in, 1.0);
        let mut os = vec![0.0f32; n_out];
        let mut ov = vec![0.0f32; n_out];
        affine_into_scalar(&x, &w, &b, &mut os);
        affine_into_vector(&x, &w, &b, &mut ov);
        for c in 0..n_out {
            assert_eq!(os[c].to_bits(), ov[c].to_bits(), "({n_in},{n_out}) col {c}");
        }
        if n_in == 0 {
            assert_eq!(os, b);
        }
    }
}

#[test]
fn forward_blocked_is_tier_invariant_and_tracks_forward() {
    let _guard = TIER_LOCK.lock().unwrap();
    let mut rng = Rng::new(0xA17);
    let shape = MlpShape { input: 31, hidden: [16, 12, 8], output: 7 };
    let p = MlpParams::init(&shape, &mut rng);
    let d = Matrix::from_vec(9, 31, rand_vec(&mut rng, 9 * 31, 1.0));

    set_kernel_tier(KernelTier::Scalar);
    let scalar = forward_blocked(&p, &d);
    set_kernel_tier(KernelTier::Simd);
    let simd = forward_blocked(&p, &d);
    set_kernel_tier(KernelTier::Auto);

    assert_eq!(scalar.data.len(), simd.data.len());
    for (a, b) in scalar.data.iter().zip(simd.data.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "forward_blocked must be tier-invariant");
    }
    // ... and both track the serial per-row oracle within the documented
    // 1e-6 band (identical accumulation order, zero-skip aside)
    let oracle = forward(&p, &d);
    let diff = oracle.max_abs_diff(&scalar);
    assert!(diff <= 1e-6, "blocked vs serial forward: {diff}");
}

#[test]
fn blocked_gradient_is_tier_invariant_and_tracks_oracle() {
    let _guard = TIER_LOCK.lock().unwrap();
    let mut rng = Rng::new(0xA18);
    let n = 120;
    let k = 7;
    let x = Matrix::from_vec(n, k, rand_vec(&mut rng, n * k, 1.0));
    let mut delta = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            if i != j {
                let v = lmds_ose::strdist::euclidean(x.row(i), x.row(j)) as f32;
                delta.set(i, j, v * 1.1 + 0.05);
            }
        }
    }

    set_kernel_tier(KernelTier::Scalar);
    let (gs, ss) = stress_gradient_blocked(&x, &delta);
    set_kernel_tier(KernelTier::Simd);
    let (gv, sv) = stress_gradient_blocked(&x, &delta);
    set_kernel_tier(KernelTier::Auto);

    assert_eq!(ss.to_bits(), sv.to_bits(), "sigma must be tier-invariant");
    for (a, b) in gs.data.iter().zip(gv.data.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "gradient must be tier-invariant");
    }

    // scale-aware band vs the f64 serial oracle (as backend_parity.rs)
    let (go, so) = stress_gradient(&x, &delta);
    let gmax = go.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let diff = go.max_abs_diff(&gs);
    assert!(diff <= 1e-3 * (1.0 + gmax), "blocked vs oracle gradient: {diff}");
    assert!((so - ss).abs() <= 1e-5 * (1.0 + so.abs()), "sigma band: {so} vs {ss}");
}

#[test]
fn vector_tier_present_on_x86_ci() {
    // Not an assert — a loud marker in the test output so a CI log shows
    // which tier the bit-equality suites actually exercised.
    println!(
        "kernel parity ran with simd_supported = {} on {}",
        simd_supported(),
        std::env::consts::ARCH
    );
}
