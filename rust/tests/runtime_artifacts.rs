//! Integration: PJRT artifacts vs their pure-Rust mirrors.
//!
//! These tests are the cross-layer correctness signal of the whole stack:
//! the same math must come out of (a) the Pallas kernels lowered through
//! JAX -> HLO text -> xla_extension 0.5.1 -> CPU PJRT, and (b) the
//! hand-written Rust implementations. Numerics are f32 on both sides, so
//! tolerances are ~1e-3 after five compounding iterations.
//!
//! All tests skip gracefully when `make artifacts` has not been run.
//!
//! The whole suite only exists under the `pjrt` cargo feature — the
//! default build has no artifact runtime to exercise (the native backend
//! is covered by `backend_parity.rs`).

#![cfg(feature = "pjrt")]

use std::sync::Mutex;

use once_cell::sync::Lazy;

use lmds_ose::mds::{lsmds, Matrix};
use lmds_ose::nn::{self, MlpParams, MlpShape};
use lmds_ose::ose;
use lmds_ose::runtime::{default_artifact_dir, OwnedArg, RuntimeHandle, RuntimeThread};
use lmds_ose::strdist::euclidean;
use lmds_ose::util::prng::Rng;

static RT: Lazy<Option<Mutex<RuntimeThread>>> = Lazy::new(|| {
    RuntimeThread::spawn(&default_artifact_dir()).ok().map(Mutex::new)
});

fn handle() -> Option<RuntimeHandle> {
    RT.as_ref().map(|m| m.lock().unwrap().handle())
}

macro_rules! require_runtime {
    () => {
        match handle() {
            Some(h) => h,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

const SMOKE_L: usize = 32;
const SMOKE_K: usize = 7;
const SMOKE_T: usize = 5;

fn smoke_shape() -> MlpShape {
    MlpShape { input: SMOKE_L, hidden: [32, 16, 8], output: SMOKE_K }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn ose_opt_artifact_matches_rust_majorization() {
    let h = require_runtime!();
    let mut rng = Rng::new(1);
    let lm = Matrix::random_normal(&mut rng, SMOKE_L, SMOKE_K, 1.0);
    let deltas = Matrix::from_vec(
        8,
        SMOKE_L,
        (0..8 * SMOKE_L).map(|_| rng.next_f32() * 3.0 + 0.5).collect(),
    );
    let lr = 1.0f32 / (2.0 * SMOKE_L as f32);

    let out = h
        .execute_graph(
            "ose_opt",
            &[("L", SMOKE_L), ("B", 8), ("T", SMOKE_T)],
            vec![
                OwnedArg::Mat(lm.clone()),
                OwnedArg::Mat(deltas.clone()),
                OwnedArg::Mat(Matrix::zeros(8, SMOKE_K)),
                OwnedArg::Scalar(lr),
            ],
        )
        .unwrap();
    let y_pjrt = out[0].clone().into_matrix();
    let sres_pjrt = &out[1];

    // Rust mirror: T explicit GD steps at the same lr from the same zeros
    let mut y_rust = Matrix::zeros(8, SMOKE_K);
    for _ in 0..SMOKE_T {
        for r in 0..8 {
            let (_, grad) =
                ose::optimise::objective_and_grad(&lm, deltas.row(r), y_rust.row(r));
            for c in 0..SMOKE_K {
                let v = y_rust.at(r, c) - lr * grad[c] as f32;
                y_rust.set(r, c, v);
            }
        }
    }
    assert!(
        y_pjrt.max_abs_diff(&y_rust) < 1e-3,
        "coords diverge: {}",
        y_pjrt.max_abs_diff(&y_rust)
    );
    // reported objective matches Eq. 2 at the final iterate
    for r in 0..8 {
        let (obj, _) =
            ose::optimise::objective_and_grad(&lm, deltas.row(r), y_pjrt.row(r));
        assert!(
            (obj - sres_pjrt.data[r] as f64).abs() < 1e-2 * (1.0 + obj),
            "row {r}: {obj} vs {}",
            sres_pjrt.data[r]
        );
    }
}

#[test]
fn mlp_fwd_artifact_matches_rust_forward() {
    let h = require_runtime!();
    let mut rng = Rng::new(2);
    let params = MlpParams::init(&smoke_shape(), &mut rng);
    let d = Matrix::from_vec(
        8,
        SMOKE_L,
        (0..8 * SMOKE_L).map(|_| rng.next_f32() * 4.0).collect(),
    );

    let spec = h
        .manifest()
        .find("mlp_fwd", &[("L", SMOKE_L), ("B", 8)])
        .unwrap()
        .clone();
    let mut args = vec![OwnedArg::Mat(d.clone())];
    for (flat, aspec) in params.flatten().into_iter().zip(spec.args.iter().skip(1)) {
        args.push(if aspec.shape.len() == 2 {
            OwnedArg::Mat(Matrix::from_vec(aspec.shape[0], aspec.shape[1], flat))
        } else {
            OwnedArg::Vec1(flat)
        });
    }
    let out = h.execute(&spec.name, args).unwrap();
    let y_pjrt = out[0].clone().into_matrix();
    let y_rust = nn::forward(&params, &d);
    assert!(
        y_pjrt.max_abs_diff(&y_rust) < 1e-4,
        "forward diverges: {}",
        y_pjrt.max_abs_diff(&y_rust)
    );
}

#[test]
fn mlp_train_step_artifact_matches_rust_adam() {
    let h = require_runtime!();
    let mut rng = Rng::new(3);
    let shape = smoke_shape();
    let mut params_rust = MlpParams::init(&shape, &mut rng);
    let flat = params_rust.flatten();
    let b = 16;
    let d = Matrix::from_vec(
        b,
        SMOKE_L,
        (0..b * SMOKE_L).map(|_| rng.next_f32() * 4.0).collect(),
    );
    let x = Matrix::random_normal(&mut rng, b, SMOKE_K, 1.0);
    let lr = 1e-3f32;

    let spec = h
        .manifest()
        .find("mlp_train_step", &[("L", SMOKE_L), ("B", b)])
        .unwrap()
        .clone();
    let mut args: Vec<OwnedArg> = Vec::new();
    for (i, p) in flat.iter().enumerate() {
        let sh = &spec.args[i].shape;
        args.push(if sh.len() == 2 {
            OwnedArg::Mat(Matrix::from_vec(sh[0], sh[1], p.clone()))
        } else {
            OwnedArg::Vec1(p.clone())
        });
    }
    for i in 0..16 {
        let sh = &spec.args[8 + i].shape;
        let zeros = vec![0.0f32; sh.iter().product::<usize>().max(1)];
        args.push(if sh.len() == 2 {
            OwnedArg::Mat(Matrix::from_vec(sh[0], sh[1], zeros))
        } else {
            OwnedArg::Vec1(zeros)
        });
    }
    args.push(OwnedArg::Scalar(0.0)); // t
    args.push(OwnedArg::Mat(d.clone()));
    args.push(OwnedArg::Mat(x.clone()));
    args.push(OwnedArg::Scalar(lr));
    let out = h.execute(&spec.name, args).unwrap();

    // Rust mirror: one backward + Adam step
    let (loss_rust, grads) = nn::backward(&params_rust, &d, &x);
    let mut adam = nn::Adam::new(&shape, lr);
    adam.step(&mut params_rust, &grads);

    // loss (output 25) matches
    let loss_pjrt = out[25].scalar() as f64;
    assert!(
        (loss_pjrt - loss_rust).abs() < 1e-3 * (1.0 + loss_rust),
        "loss: {loss_pjrt} vs {loss_rust}"
    );
    // t incremented
    assert_eq!(out[24].scalar(), 1.0);
    // updated parameters match
    let updated = params_rust.flatten();
    for (i, want) in updated.iter().enumerate() {
        let got = &out[i].data;
        assert!(
            max_abs_diff(got, want) < 2e-3,
            "param {i} diverges by {}",
            max_abs_diff(got, want)
        );
    }
}

#[test]
fn mlp_loss_artifact_matches_rust_loss() {
    let h = require_runtime!();
    let mut rng = Rng::new(4);
    let params = MlpParams::init(&smoke_shape(), &mut rng);
    let b = 16;
    let d = Matrix::from_vec(
        b,
        SMOKE_L,
        (0..b * SMOKE_L).map(|_| rng.next_f32() * 4.0).collect(),
    );
    let x = Matrix::random_normal(&mut rng, b, SMOKE_K, 1.0);

    let spec = h
        .manifest()
        .find("mlp_loss", &[("L", SMOKE_L), ("B", b)])
        .unwrap()
        .clone();
    let mut args: Vec<OwnedArg> = Vec::new();
    for (i, p) in params.flatten().into_iter().enumerate() {
        let sh = &spec.args[i].shape;
        args.push(if sh.len() == 2 {
            OwnedArg::Mat(Matrix::from_vec(sh[0], sh[1], p))
        } else {
            OwnedArg::Vec1(p)
        });
    }
    args.push(OwnedArg::Mat(d.clone()));
    args.push(OwnedArg::Mat(x.clone()));
    let out = h.execute(&spec.name, args).unwrap();
    let want = nn::mae_loss(&nn::forward(&params, &d), &x);
    let got = out[0].scalar() as f64;
    assert!((got - want).abs() < 1e-4 * (1.0 + want), "{got} vs {want}");
}

#[test]
fn lsmds_steps_artifact_matches_rust_gd() {
    let h = require_runtime!();
    let n = 64;
    let mut rng = Rng::new(5);
    // realizable dissimilarities from a hidden 7-D configuration
    let hidden = Matrix::random_normal(&mut rng, n, SMOKE_K, 1.0);
    let mut delta = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            delta.set(i, j, euclidean(hidden.row(i), hidden.row(j)) as f32);
        }
    }
    let mut x0 = Matrix::random_normal(&mut rng, n, SMOKE_K, 1.0);
    x0.center_columns();
    let lr = 1.0f32 / (2.0 * n as f32);

    let out = h
        .execute_graph(
            "lsmds_steps",
            &[("N", n), ("T", SMOKE_T)],
            vec![
                OwnedArg::Mat(x0.clone()),
                OwnedArg::Mat(delta.clone()),
                OwnedArg::Scalar(lr),
            ],
        )
        .unwrap();
    let x_pjrt = out[0].clone().into_matrix();
    let sigma_pjrt = out[1].scalar() as f64;

    // Rust mirror
    let mut x_rust = x0.clone();
    let mut sigma_rust = 0.0f64;
    for _ in 0..SMOKE_T {
        let (grad, sigma) = lmds_ose::mds::lsmds::stress_gradient(&x_rust, &delta);
        sigma_rust = sigma;
        for (v, g) in x_rust.data.iter_mut().zip(grad.data.iter()) {
            *v -= lr * g;
        }
    }
    assert!(
        x_pjrt.max_abs_diff(&x_rust) < 2e-3,
        "configs diverge: {}",
        x_pjrt.max_abs_diff(&x_rust)
    );
    assert!(
        (sigma_pjrt - sigma_rust).abs() < 1e-2 * (1.0 + sigma_rust),
        "sigma: {sigma_pjrt} vs {sigma_rust}"
    );
}

#[test]
fn iterated_lsmds_artifact_reduces_stress_like_rust_solver() {
    let _h = require_runtime!();
    let Ok(backend) = lmds_ose::runtime::Backend::pjrt(&default_artifact_dir()) else {
        eprintln!("skipping: pjrt backend unavailable");
        return;
    };
    let n = 64;
    let mut rng = Rng::new(6);
    let hidden = Matrix::random_normal(&mut rng, n, 3, 1.0);
    let mut delta = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            delta.set(i, j, euclidean(hidden.row(i), hidden.row(j)) as f32);
        }
    }
    // artifact-driven solve via the embedder helper
    let cfg = lmds_ose::mds::LsmdsConfig {
        dim: SMOKE_K,
        max_iters: 100,
        seed: 7,
        ..Default::default()
    };
    let (x, stress) =
        lmds_ose::coordinator::embedder::lsmds_landmarks(&delta, &cfg, &backend)
            .unwrap();
    assert_eq!((x.rows, x.cols), (n, SMOKE_K));
    // embedding 3-D data in 7-D: should reach low stress
    let rust = lsmds(&delta, &cfg);
    assert!(
        stress < rust.normalized_stress + 0.05,
        "artifact solve stress {stress} vs rust {}",
        rust.normalized_stress
    );
}

#[test]
fn execute_rejects_wrong_shapes_and_names() {
    let h = require_runtime!();
    // wrong arg count
    assert!(h
        .execute_graph("ose_opt", &[("L", SMOKE_L), ("B", 8)], vec![])
        .is_err());
    // wrong shape
    assert!(h
        .execute_graph(
            "ose_opt",
            &[("L", SMOKE_L), ("B", 8)],
            vec![
                OwnedArg::Mat(Matrix::zeros(SMOKE_L + 1, SMOKE_K)),
                OwnedArg::Mat(Matrix::zeros(8, SMOKE_L)),
                OwnedArg::Mat(Matrix::zeros(8, SMOKE_K)),
                OwnedArg::Scalar(0.1),
            ],
        )
        .is_err());
    // unknown artifact
    assert!(h.execute("nope__X1", vec![]).is_err());
    // warm succeeds for a real one
    let name = h
        .manifest()
        .find("mlp_fwd", &[("L", SMOKE_L), ("B", 8)])
        .unwrap()
        .name
        .clone();
    h.warm(&name).unwrap();
}
