//! Peak-RSS guarantee of the out-of-core pipeline, enforced by a
//! tracking global allocator: embedding an N-record corpus end-to-end
//! (divide base solve + streamed OSE, both fed from disk) must fit a
//! budget of O(cache + L² + stream chunks + N·K output) — strictly below
//! what the materialised equivalent allocates for its `N x L`
//! dissimilarity matrix alone, let alone an `N x N` delta matrix. This
//! file holds exactly one test so the allocator counters see no
//! concurrent neighbours.
//!
//! The table is opened through the *pread* backend on purpose: its block
//! cache lives on the heap where this allocator can see it, so the run
//! demonstrates the explicit byte budget. (mmap residency is OS-managed
//! and invisible to a heap profiler — trivially "zero" here.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use lmds_ose::coordinator::embedder::{
    embed_corpus, BaseSolver, OseBackend, PipelineConfig,
};
use lmds_ose::data::source::{CorpusWriter, ObjectTable, TableDelta};
use lmds_ose::data::synthetic::gaussian_clusters;
use lmds_ose::mds::{LandmarkMethod, LsmdsConfig};
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Euclidean;
use lmds_ose::util::prng::Rng;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
static LARGEST: AtomicUsize = AtomicUsize::new(0);

struct TrackingAlloc;

impl TrackingAlloc {
    fn on_alloc(size: usize) {
        let live = LIVE.fetch_add(size, Ordering::Relaxed) + size;
        PEAK.fetch_max(live, Ordering::Relaxed);
        LARGEST.fetch_max(size, Ordering::Relaxed);
    }
}

unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            Self::on_alloc(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc_zeroed(layout) };
        if !p.is_null() {
            Self::on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) };
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
            Self::on_alloc(new_size);
        }
        p
    }
}

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

#[test]
fn out_of_core_embed_stays_within_heap_budget() {
    // Release (the CI `cargo test --release` job) runs the full N = 100k;
    // the debug tier-1 run scales to 20k. The budget maths are identical.
    let n: usize = if cfg!(debug_assertions) { 20_000 } else { 100_000 };
    let l = 300usize;
    let dim = 8usize; // stored record width
    let k = 7usize; // embedding dimension
    let chunk = 512usize;
    let cache_budget = 8 << 20;

    // -- setup: write the corpus (bounded batches; pre-measurement) --
    let mut path = std::env::temp_dir();
    path.push(format!("lmds_ooc_mem_{n}_{}", std::process::id()));
    {
        let mut w = CorpusWriter::create_vectors(&path, dim).unwrap();
        let mut rng = Rng::new(0x00C);
        let mut written = 0usize;
        while written < n {
            let batch = (n - written).min(8192);
            for row in gaussian_clusters(&mut rng, batch, dim, 8, 1.0) {
                w.push_vector(&row).unwrap();
            }
            written += batch;
        }
        w.finish().unwrap();
    }

    let monolithic_bytes = n * l * 4; // the N x L delta of the in-RAM path
    let full_delta_bytes = n * n * 4; // the N x N matrix nobody can hold
    let budget_bytes = cache_budget  // pread block cache (hard budget)
        + l * l * 4 * 2              // divide block sub-matrices + slack
        + 2 * chunk * l * 4          // the two in-flight stream blocks
        + n * k * 4                  // the N x K output
        + n * 8                      // rest-index bookkeeping
        + (8 << 20); // slack: thread-pool scratch, per-chunk rows, harness
    assert!(
        budget_bytes < monolithic_bytes,
        "the test budget ({budget_bytes} B) must be smaller than one \
         monolithic N x L matrix ({monolithic_bytes} B), or it proves nothing"
    );

    let cfg = PipelineConfig {
        dim: k,
        landmarks: l,
        // random selection: FPS would be correct too, but O(L·N) serial
        // dist calls through the cache dominate debug wall-clock
        landmark_method: LandmarkMethod::Random,
        backend: OseBackend::Opt,
        lsmds: LsmdsConfig { dim: k, max_iters: 60, ..Default::default() },
        base_solver: BaseSolver::DivideConquer { blocks: 4, anchors: 0 },
        stream_chunk: Some(chunk),
        ose_steps: Some(4), // fixed work: memory profile is the subject
        ..Default::default()
    };

    // -- measured region --
    let baseline = LIVE.load(Ordering::Relaxed);
    PEAK.store(baseline, Ordering::Relaxed);
    LARGEST.store(0, Ordering::Relaxed);

    let table = ObjectTable::open_pread(&path, cache_budget).unwrap();
    let source = TableDelta::vectors(&table, &Euclidean).unwrap();
    let result = embed_corpus(&source, &cfg, &Backend::native()).unwrap();

    let peak_extra = PEAK.load(Ordering::Relaxed).saturating_sub(baseline);
    let largest = LARGEST.load(Ordering::Relaxed);
    // -- end measured region --

    assert_eq!((result.coords.rows, result.coords.cols), (n, k));
    assert!(result.coords.data.iter().all(|v| v.is_finite()));
    assert_eq!(result.landmark_idx.len(), l);
    let cache = table.cache_stats().expect("pread backend has a cache");
    assert!(
        cache.resident_bytes <= cache_budget.max(1 << 20),
        "cache broke its budget: {cache:?}"
    );

    // no N x L (let alone N x N) allocation anywhere on the path
    assert!(
        largest < monolithic_bytes / 2,
        "largest single allocation {largest} B is within 2x of a \
         monolithic N x L matrix ({monolithic_bytes} B) — something \
         materialised the out-of-sample block"
    );
    // the whole transient footprint beats the materialised equivalent
    assert!(
        peak_extra < budget_bytes,
        "peak transient memory {peak_extra} B exceeds the out-of-core \
         budget {budget_bytes} B (monolithic N x L = {monolithic_bytes} B, \
         full N x N delta = {full_delta_bytes} B)"
    );

    std::fs::remove_file(&path).ok();
}
