//! Integration: the binary wire protocol and the network front door.
//! Portable tests pin the public framing API (round trips through the
//! `Deframer`, stable error codes); the Linux-gated suite runs the whole
//! stack over real loopback sockets — queries, pipelining, protocol
//! violations, and deterministic load shedding.

use lmds_ose::coordinator::error::{
    CODE_BAD_INPUT, CODE_OVERLOADED, CODE_PROTOCOL,
};
use lmds_ose::coordinator::{Deframer, Frame, ServeError};
use lmds_ose::util::quickcheck::{prop_assert, property};

#[test]
fn public_framing_api_round_trips_through_byte_dribble() {
    property("public deframer round-trip", 60, |g| {
        let frames = vec![
            Frame::Ping { id: g.u64() },
            Frame::QueryText { id: g.u64(), text: g.unicode_string(0, 32) },
            Frame::QueryDelta { id: g.u64(), delta: g.vec_f32(0, 48, 8.0) },
            Frame::Result {
                id: g.u64(),
                degraded: g.bool(),
                latency_us: g.u64() as u32,
                coords: g.vec_f32(1, 8, 3.0),
            },
            Frame::from_error(
                g.u64(),
                &ServeError::ShardUnavailable {
                    shard: g.usize_in(0, 7),
                    reason: g.string(0, 12),
                },
            ),
        ];
        let mut wire = Vec::new();
        for f in &frames {
            f.encode(&mut wire);
        }
        let mut d = Deframer::new();
        let mut got = Vec::new();
        let mut off = 0;
        while off < wire.len() {
            let step = g.usize_in(1, 9).min(wire.len() - off);
            d.extend(&wire[off..off + step]);
            off += step;
            while let Some(f) = d.next().map_err(|e| e.to_string())? {
                got.push(f);
            }
        }
        if got != frames {
            return Err(format!("dribbled {} frames, got {}", frames.len(), got.len()));
        }
        prop_assert(d.buffered() == 0, "no leftover bytes")
    });
}

#[test]
fn wire_error_codes_are_stable_across_the_public_api() {
    // the code table is wire ABI: clients hard-code these numbers
    let table = [
        (ServeError::BadInput { reason: "x".into() }, 1u16),
        (ServeError::Overloaded, 2),
        (ServeError::Shutdown, 3),
        (ServeError::ReplicaPanic { reason: "x".into() }, 4),
        (ServeError::ShardUnavailable { shard: 9, reason: "x".into() }, 5),
        (ServeError::Timeout, 6),
        (ServeError::Protocol { reason: "x".into() }, 7),
        (ServeError::Internal { reason: "x".into() }, 8),
    ];
    for (e, want) in table {
        assert_eq!(e.wire_code(), want, "{e:?}");
        let f = Frame::from_error(3, &e);
        match &f {
            Frame::Error { code, .. } => assert_eq!(*code, want),
            other => panic!("expected an error frame, got {other:?}"),
        }
        assert_eq!(f.to_error(), Some(e));
    }
}

#[cfg(target_os = "linux")]
mod loopback {
    use std::io::Write as _;
    use std::net::TcpStream;
    use std::sync::Arc;
    use std::time::Duration;

    use lmds_ose::coordinator::methods::BackendOpt;
    use lmds_ose::coordinator::proto::{read_frame, write_frame, MAX_FRAME};
    use lmds_ose::coordinator::{
        BatcherConfig, Frame, NetConfig, NetServer, Server, ServerBuilder,
        ServerHandle,
    };
    use lmds_ose::mds::Matrix;
    use lmds_ose::runtime::Backend;
    use lmds_ose::strdist::Levenshtein;
    use lmds_ose::util::prng::Rng;

    use super::{CODE_BAD_INPUT, CODE_OVERLOADED, CODE_PROTOCOL};

    const L: usize = 16;
    const K: usize = 3;

    /// A small str server: Levenshtein deltas into an optimisation OSE
    /// over a random landmark configuration (frame flow is under test,
    /// not embedding quality).
    fn start_server() -> (Server<str>, ServerHandle<str>) {
        let mut rng = Rng::new(0x9e7);
        let config = Matrix::random_normal(&mut rng, L, K, 1.0);
        let landmarks: Vec<String> = (0..L).map(|i| format!("landmark{i:02}")).collect();
        let server = ServerBuilder::strings(
            landmarks,
            Arc::new(Levenshtein),
            BackendOpt::replica_factory_budget(Backend::native(), config, 60),
        )
        .batcher(BatcherConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            queue_cap: 1024,
            frontend_threads: 2,
            replicas: 1,
        })
        .build()
        .expect("valid server configuration");
        let h = server.handle();
        (server, h)
    }

    fn connect(front: &NetServer) -> TcpStream {
        let conn = TcpStream::connect(front.local_addr()).expect("connect");
        conn.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
        conn.set_nodelay(true).ok();
        conn
    }

    #[test]
    fn wire_protocol_serves_queries_over_loopback() {
        let (server, h) = start_server();
        let front = NetServer::start(
            Arc::new(h.clone()),
            NetConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .expect("front door starts");
        let mut conn = connect(&front);

        write_frame(&mut conn, &Frame::Ping { id: 7 }).unwrap();
        assert_eq!(read_frame(&mut conn).unwrap(), Frame::Pong { id: 7 });

        write_frame(&mut conn, &Frame::QueryText { id: 1, text: "anna".into() })
            .unwrap();
        match read_frame(&mut conn).unwrap() {
            Frame::Result { id, degraded, coords, .. } => {
                assert_eq!(id, 1);
                assert!(!degraded);
                assert_eq!(coords.len(), K);
                assert!(coords.iter().all(|c| c.is_finite()));
            }
            other => panic!("expected a result frame, got {other:?}"),
        }

        write_frame(&mut conn, &Frame::QueryDelta { id: 2, delta: vec![1.5; L] })
            .unwrap();
        match read_frame(&mut conn).unwrap() {
            Frame::Result { id, coords, .. } => {
                assert_eq!(id, 2);
                assert_eq!(coords.len(), K);
            }
            other => panic!("expected a result frame, got {other:?}"),
        }

        // invalid query: typed error frame, connection stays usable
        write_frame(&mut conn, &Frame::QueryDelta { id: 3, delta: vec![1.0; L + 2] })
            .unwrap();
        match read_frame(&mut conn).unwrap() {
            Frame::Error { id, code, message, .. } => {
                assert_eq!(id, 3);
                assert_eq!(code, CODE_BAD_INPUT);
                assert!(message.contains("one per landmark"), "{message}");
            }
            other => panic!("expected an error frame, got {other:?}"),
        }
        write_frame(&mut conn, &Frame::Ping { id: 8 }).unwrap();
        assert_eq!(read_frame(&mut conn).unwrap(), Frame::Pong { id: 8 });

        let snap = h.metrics.snapshot();
        assert!(snap.conns_opened >= 1);
        assert_eq!(snap.proto_errors, 0);
        front.shutdown();
        drop(conn);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn pipelined_queries_over_one_connection_all_answer() {
        let (server, h) = start_server();
        let front = NetServer::start(
            Arc::new(h.clone()),
            NetConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .expect("front door starts");
        let mut conn = connect(&front);
        let n = 200u64;
        for id in 0..n {
            write_frame(&mut conn, &Frame::QueryDelta { id, delta: vec![1.0; L] })
                .unwrap();
        }
        // completion order is the batcher's business; ids must form the
        // exact request set
        let mut seen: Vec<u64> = (0..n)
            .map(|_| match read_frame(&mut conn).unwrap() {
                Frame::Result { id, coords, .. } => {
                    assert_eq!(coords.len(), K);
                    id
                }
                other => panic!("expected a result frame, got {other:?}"),
            })
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "every id exactly once");
        assert_eq!(h.metrics.snapshot().completed, n);
        front.shutdown();
        drop(conn);
        drop(h);
        server.shutdown();
    }

    #[test]
    fn protocol_violations_get_a_typed_reply_then_the_connection_closes() {
        let (server, h) = start_server();
        let front = NetServer::start(
            Arc::new(h.clone()),
            NetConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .expect("front door starts");

        // oversized length prefix
        let mut conn = connect(&front);
        conn.write_all(&((MAX_FRAME as u32) + 1).to_le_bytes()).unwrap();
        match read_frame(&mut conn).unwrap() {
            Frame::Error { code, .. } => assert_eq!(code, CODE_PROTOCOL),
            other => panic!("expected a protocol error frame, got {other:?}"),
        }
        assert!(
            read_frame(&mut conn).is_err(),
            "server must close after a framing violation"
        );

        // a client sending a server-side frame is also a violation
        let mut conn = connect(&front);
        write_frame(&mut conn, &Frame::Pong { id: 4 }).unwrap();
        match read_frame(&mut conn).unwrap() {
            Frame::Error { id, code, .. } => {
                assert_eq!(id, 4);
                assert_eq!(code, CODE_PROTOCOL);
            }
            other => panic!("expected a protocol error frame, got {other:?}"),
        }
        assert!(read_frame(&mut conn).is_err());

        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while h.metrics.snapshot().proto_errors < 2 {
            assert!(deadline > std::time::Instant::now(), "proto errors uncounted");
            std::thread::sleep(Duration::from_millis(5));
        }
        front.shutdown();
        drop(h);
        server.shutdown();
    }

    #[test]
    fn saturated_front_door_sheds_load_with_overloaded_replies() {
        let (server, h) = start_server();
        // max_in_flight 0: every query is load-shed — the deterministic
        // worst case of the backpressure path
        let front = NetServer::start(
            Arc::new(h.clone()),
            NetConfig {
                addr: "127.0.0.1:0".into(),
                max_in_flight: 0,
                ..Default::default()
            },
        )
        .expect("front door starts");
        let mut conn = connect(&front);
        for id in 0..5u64 {
            write_frame(&mut conn, &Frame::QueryDelta { id, delta: vec![1.0; L] })
                .unwrap();
            match read_frame(&mut conn).unwrap() {
                Frame::Error { id: rid, code, .. } => {
                    assert_eq!(rid, id);
                    assert_eq!(code, CODE_OVERLOADED);
                }
                other => panic!("expected an overloaded reply, got {other:?}"),
            }
        }
        // shedding is cheap rejection, not failure: pings still flow
        write_frame(&mut conn, &Frame::Ping { id: 99 }).unwrap();
        assert_eq!(read_frame(&mut conn).unwrap(), Frame::Pong { id: 99 });
        let snap = h.metrics.snapshot();
        assert_eq!(snap.shed, 5);
        assert_eq!(snap.completed, 0);
        front.shutdown();
        drop(h);
        server.shutdown();
    }
}
