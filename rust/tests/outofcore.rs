//! Out-of-core data-source contracts: the disk-backed [`TableDelta`]
//! must be *indistinguishable* from the in-memory sources it replaces —
//! bit-for-bit through the base solvers — and the end-to-end corpus
//! pipeline must agree across storage backends, cache budgets and
//! stream chunkings.

use std::path::PathBuf;

use lmds_ose::coordinator::embedder::{
    embed_corpus, solve_base, solve_base_source, BaseSolver, OseBackend,
    PipelineConfig,
};
use lmds_ose::data::source::{
    mmap_supported, CorpusWriter, ObjectTable, TableDelta, DEFAULT_CACHE_BUDGET,
};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::full_matrix;
use lmds_ose::mds::divide::{DeltaSource, PointsDelta, SubsetDelta};
use lmds_ose::mds::{LsmdsConfig, Matrix};
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::{Euclidean, Levenshtein};
use lmds_ose::util::prng::Rng;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lmds_ooc_{name}_{}", std::process::id()));
    p
}

/// Seeded coordinate data + its corpus file, returned as (points, path).
fn vec_corpus(name: &str, seed: u64, n: usize, dim: usize) -> (Matrix, PathBuf) {
    let mut rng = Rng::new(seed);
    let points = Matrix::random_normal(&mut rng, n, dim, 1.0);
    let path = tmp(name);
    let mut w = CorpusWriter::create_vectors(&path, dim).unwrap();
    for i in 0..n {
        w.push_vector(points.row(i)).unwrap();
    }
    w.finish().unwrap();
    (points, path)
}

/// Seeded Geco names + their corpus file.
fn text_corpus(name: &str, seed: u64, n: usize) -> (Vec<String>, PathBuf) {
    let mut geco = Geco::new(GecoConfig { seed, ..Default::default() });
    let names = geco.generate_unique(n);
    let path = tmp(name);
    let mut w = CorpusWriter::create_text(&path).unwrap();
    for s in &names {
        w.push_text(s).unwrap();
    }
    w.finish().unwrap();
    (names, path)
}

/// Every storage backend available in this build, smallest budgets last
/// so eviction paths run under the same assertions.
fn tables(path: &PathBuf) -> Vec<(ObjectTable, &'static str)> {
    let mut v = vec![
        (ObjectTable::open_pread(path, DEFAULT_CACHE_BUDGET), "pread/64MiB"),
        (ObjectTable::open_pread(path, 4 << 10), "pread/4KiB"),
    ];
    #[cfg(all(unix, target_pointer_width = "64"))]
    v.push((ObjectTable::open_mmap(path), "mmap"));
    assert_eq!(mmap_supported(), v.len() == 3);
    v.into_iter().map(|(t, n)| (t.unwrap(), n)).collect()
}

#[test]
fn disk_source_matches_points_delta_and_matrix_bitwise() {
    let (points, path) = vec_corpus("bits", 0xD15C, 120, 4);
    let ram = PointsDelta { points: &points };
    let refs: Vec<&[f32]> = (0..points.rows).map(|i| points.row(i)).collect();
    let materialised = full_matrix(&refs, &Euclidean);
    for (table, label) in tables(&path) {
        let disk = TableDelta::vectors(&table, &Euclidean).unwrap();
        assert_eq!(disk.len(), 120, "{label}");
        for i in (0..120).step_by(3) {
            for j in (0..120).step_by(7) {
                let d = disk.dist(i, j);
                assert!(
                    d == ram.dist(i, j) && d == materialised.at(i, j),
                    "{label}: ({i},{j}) disk {d} ram {} mat {}",
                    ram.dist(i, j),
                    materialised.at(i, j)
                );
            }
        }
        // sub-matrices too (the unit the divide solver actually reads)
        let idx = [0usize, 17, 33, 64, 119];
        let a = disk.sub_matrix(&idx);
        let b = ram.sub_matrix(&idx);
        assert_eq!(a.data, b.data, "{label}");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn disk_text_source_matches_full_matrix_bitwise() {
    let (names, path) = text_corpus("txt_bits", 0x7e47, 90);
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let materialised = full_matrix(&refs, &Levenshtein);
    for (table, label) in tables(&path) {
        let disk = TableDelta::text(&table, &Levenshtein).unwrap();
        for i in (0..90).step_by(2) {
            for j in (0..90).step_by(5) {
                assert_eq!(disk.dist(i, j), materialised.at(i, j), "{label} ({i},{j})");
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn subset_delta_is_the_restricted_view() {
    let (points, path) = vec_corpus("subset", 0x5b5e, 60, 3);
    let table = ObjectTable::open(&path, DEFAULT_CACHE_BUDGET).unwrap();
    let disk = TableDelta::vectors(&table, &Euclidean).unwrap();
    let idx = [3usize, 9, 9, 30, 59]; // duplicates are legal
    let sub = SubsetDelta::new(&disk, &idx);
    assert_eq!(sub.len(), 5);
    assert_eq!(sub.indices(), &idx);
    for a in 0..5 {
        for b in 0..5 {
            assert_eq!(sub.dist(a, b), disk.dist(idx[a], idx[b]));
        }
    }
    assert_eq!(sub.dist(1, 2), 0.0, "duplicate indices are coincident");
    // sub_matrix delegates through the source with mapped indices
    let m = sub.sub_matrix(&[0, 2, 4]);
    let ram = PointsDelta { points: &points };
    let want = ram.sub_matrix(&[3, 9, 59]);
    assert_eq!(m.data, want.data);
    std::fs::remove_file(&path).ok();
}

#[test]
#[should_panic(expected = "subset index out of range")]
fn subset_delta_rejects_out_of_range_indices() {
    let points = Matrix::zeros(4, 2);
    let src = PointsDelta { points: &points };
    let idx = [0usize, 4];
    let _ = SubsetDelta::new(&src, &idx);
}

/// The parity the whole layer hangs on: the *same* base solve fed from
/// (a) a materialised matrix through `solve_base`, (b) the disk source
/// through `solve_base_source`, and (c) the matrix-free `PointsDelta`,
/// must produce bit-identical configurations — for both solvers.
#[test]
fn solve_base_parity_disk_vs_matrix_vs_points() {
    let (points, path) = vec_corpus("solve_parity", 0xBA5E, 150, 3);
    let ram = PointsDelta { points: &points };
    // materialise exactly what the sources serve (symmetric, zero diag)
    let all: Vec<usize> = (0..150).collect();
    let materialised = ram.sub_matrix(&all);
    let lcfg = LsmdsConfig { dim: 3, max_iters: 60, seed: 11, ..Default::default() };
    let backend = Backend::native();
    for solver in [
        BaseSolver::DivideConquer { blocks: 4, anchors: 12 },
        BaseSolver::Monolithic,
    ] {
        let (from_matrix, _) = solve_base(&materialised, &lcfg, solver, &backend).unwrap();
        let (from_points, _) =
            solve_base_source(&ram, &lcfg, solver, &backend).unwrap();
        assert_eq!(
            from_matrix.data, from_points.data,
            "{solver:?}: PointsDelta diverged from the materialised matrix"
        );
        for (table, label) in tables(&path) {
            let disk = TableDelta::vectors(&table, &Euclidean).unwrap();
            let (from_disk, _) =
                solve_base_source(&disk, &lcfg, solver, &backend).unwrap();
            assert_eq!(
                from_matrix.data, from_disk.data,
                "{solver:?} via {label}: disk source diverged"
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Same parity over a *string* corpus and a subset view (the landmark
/// sample shape the out-of-core pipeline actually solves).
#[test]
fn solve_base_parity_text_subset() {
    let (names, path) = text_corpus("txt_parity", 0x90ab, 80);
    let landmark_idx: Vec<usize> = (0..80).step_by(2).collect(); // 40 landmarks
    let lm_refs: Vec<&str> = landmark_idx.iter().map(|&i| names[i].as_str()).collect();
    let materialised = full_matrix(&lm_refs, &Levenshtein);
    let lcfg = LsmdsConfig { dim: 2, max_iters: 50, seed: 5, ..Default::default() };
    let backend = Backend::native();
    let solver = BaseSolver::DivideConquer { blocks: 3, anchors: 8 };
    let (want, _) = solve_base(&materialised, &lcfg, solver, &backend).unwrap();
    for (table, label) in tables(&path) {
        let disk = TableDelta::text(&table, &Levenshtein).unwrap();
        let sub = SubsetDelta::new(&disk, &landmark_idx);
        let (got, _) = solve_base_source(&sub, &lcfg, solver, &backend).unwrap();
        assert_eq!(want.data, got.data, "{label}");
    }
    std::fs::remove_file(&path).ok();
}

/// End-to-end: the full out-of-core pipeline must not care which storage
/// backend serves the bytes, and its OSE stage must match a from-RAM
/// re-embedding of the same rows bit-for-bit.
#[test]
fn embed_corpus_agrees_across_backends_and_with_ram_reembedding() {
    let (points, path) = vec_corpus("e2e", 0xE2E, 400, 4);
    let cfg = PipelineConfig {
        dim: 3,
        landmarks: 40,
        backend: OseBackend::Opt,
        lsmds: LsmdsConfig { max_iters: 60, dim: 3, ..Default::default() },
        base_solver: BaseSolver::DivideConquer { blocks: 3, anchors: 10 },
        stream_chunk: Some(64),
        ose_steps: Some(10), // fixed work: chunking cannot shift a bit
        ..Default::default()
    };
    let backend = Backend::native();
    let mut reference: Option<lmds_ose::coordinator::PipelineResult> = None;
    for (table, label) in tables(&path) {
        let disk = TableDelta::vectors(&table, &Euclidean).unwrap();
        let r = embed_corpus(&disk, &cfg, &backend).unwrap();
        assert_eq!((r.coords.rows, r.coords.cols), (400, 3), "{label}");
        assert!(r.coords.data.iter().all(|v| v.is_finite()), "{label}");
        match &reference {
            None => reference = Some(r),
            Some(first) => {
                assert_eq!(first.landmark_idx, r.landmark_idx, "{label}");
                assert_eq!(
                    first.coords.data, r.coords.data,
                    "{label}: storage backend changed the embedding"
                );
            }
        }
    }
    // re-embed the non-landmark rows from RAM through a fresh replica of
    // the same trained state: row-independent fixed-step embedding must
    // reproduce the streamed output exactly
    let r = reference.unwrap();
    let mut method = r.factory.build();
    let lm_refs: Vec<&[f32]> =
        r.landmark_idx.iter().map(|&i| points.row(i)).collect();
    let rest: Vec<usize> =
        (0..400).filter(|i| r.landmark_idx.binary_search(i).is_err()).collect();
    let rest_refs: Vec<&[f32]> = rest.iter().map(|&i| points.row(i)).collect();
    let block =
        lmds_ose::mds::dissimilarity::cross_matrix(&rest_refs, &lm_refs, &Euclidean);
    let coords = method.embed(&block).unwrap();
    for (row, &i) in rest.iter().enumerate() {
        assert_eq!(
            coords.row(row),
            r.coords.row(i),
            "row {i}: streamed out-of-core embedding diverged from RAM"
        );
    }
    std::fs::remove_file(&path).ok();
}

/// A cache budget far below the working set must change nothing but the
/// eviction counters.
#[test]
fn starved_cache_is_slow_but_correct() {
    let (_, path) = text_corpus("starved", 0x5740, 120);
    let roomy = ObjectTable::open_pread(&path, DEFAULT_CACHE_BUDGET).unwrap();
    let starved = ObjectTable::open_pread(&path, 256).unwrap();
    let a = TableDelta::text(&roomy, &Levenshtein).unwrap();
    let b = TableDelta::text(&starved, &Levenshtein).unwrap();
    let lcfg = LsmdsConfig { dim: 2, max_iters: 40, ..Default::default() };
    let solver = BaseSolver::DivideConquer { blocks: 2, anchors: 6 };
    let backend = Backend::native();
    let (xa, _) = solve_base_source(&a, &lcfg, solver, &backend).unwrap();
    let (xb, _) = solve_base_source(&b, &lcfg, solver, &backend).unwrap();
    assert_eq!(xa.data, xb.data);
    let stats = starved.cache_stats().unwrap();
    assert!(stats.evictions > 0, "starved cache must have evicted: {stats:?}");
    std::fs::remove_file(&path).ok();
}
