//! Chaos/soak: the drift-triggered refresh loop, end to end.
//!
//! The suite drives a live server through the full closed loop — calibrate
//! on in-distribution traffic, inject an out-of-distribution query storm,
//! watch the drift monitor fire, the controller ingest + shadow-solve +
//! swap — and then asserts the loop's contract:
//!
//! - exactly ONE refresh fires per drift episode (cooldown respected; the
//!   fresh post-swap monitor recalibrates on the new traffic);
//! - zero error replies and zero degraded replies across the whole soak,
//!   including the queries in flight during the generation swap;
//! - the warm-started shadow solve lands within 0.05 normalised stress of
//!   a from-scratch re-solve over the same grown corpus;
//! - a refresh killed mid-cycle (chaos hook) leaves the old generation
//!   serving and the corpus readable, and the next attempt recovers;
//! - serving is bit-reproducible: identical queries get bit-identical
//!   coordinates across repeats, server restarts, and the dense
//!   (`query_k = 0`) vs graph-assisted (`query_k >= L`) paths.
//!
//! Determinism: every PRNG stream derives from one seed, overridable with
//! `LMDS_SOAK_SEED` (CI pins it). Debug builds run a smaller soak so the
//! suite stays fast without `--release`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use lmds_ose::coordinator::{
    embed_corpus, solve_base_source, BaseSolver, BatcherConfig, DriftConfig,
    DriftHook, OseBackend, PipelineConfig, PipelineResult, RefreshConfig,
    RefreshController, Request, Server, ServerBuilder,
};
use lmds_ose::data::source::{
    CorpusWriter, ObjectTable, TableDelta, DEFAULT_CACHE_BUDGET,
};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::{LandmarkMethod, LsmdsConfig, SubsetDelta};
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::Levenshtein;

/// Soak seed: `LMDS_SOAK_SEED` if set (CI pins it), a fixed default
/// otherwise. Every stream in the suite derives from this.
fn soak_seed() -> u64 {
    std::env::var("LMDS_SOAK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40246)
}

/// Corpus size: debug builds soak a smaller corpus so `cargo test`
/// without `--release` stays quick; CI's soak job runs the full size.
fn soak_n() -> usize {
    if cfg!(debug_assertions) {
        400
    } else {
        1200
    }
}

fn write_corpus(tag: &str, seed: u64, n: usize) -> (std::path::PathBuf, Vec<String>) {
    let mut geco = Geco::new(GecoConfig { seed, ..Default::default() });
    let names = geco.generate_unique(n);
    let path = std::env::temp_dir().join(format!(
        "lmds_chaos_{tag}_{seed}_{n}_{}",
        std::process::id()
    ));
    let mut w = CorpusWriter::create_text(&path).unwrap();
    for name in &names {
        w.push_text(name).unwrap();
    }
    w.finish().unwrap();
    (path, names)
}

fn soak_pipeline(seed: u64) -> PipelineConfig {
    PipelineConfig {
        dim: 3,
        landmarks: 48,
        landmark_method: LandmarkMethod::Random,
        backend: OseBackend::Opt,
        base_solver: BaseSolver::DivideConquer { blocks: 4, anchors: 0 },
        lsmds: LsmdsConfig { dim: 3, max_iters: 200, ..Default::default() },
        // fixed majorization budget: bit-reproducible replies
        ose_steps: Some(6),
        seed,
        ..Default::default()
    }
}

fn embed(path: &std::path::Path, pcfg: &PipelineConfig, backend: &Backend) -> PipelineResult {
    let table = ObjectTable::open(path, DEFAULT_CACHE_BUDGET).unwrap();
    let source = TableDelta::text(&table, &Levenshtein).unwrap();
    embed_corpus(&source, pcfg, backend).unwrap()
}

fn start_server(
    path: &std::path::Path,
    r: &PipelineResult,
    backend: &Backend,
    drift: Option<DriftConfig>,
) -> Server<str> {
    let landmark_objs: Vec<String> = {
        let t = ObjectTable::open(path, DEFAULT_CACHE_BUDGET).unwrap();
        t.text_rows(&r.landmark_idx)
    };
    let mut b = ServerBuilder::strings(
        landmark_objs,
        Arc::new(Levenshtein),
        Arc::clone(&r.factory),
    )
    .batcher(BatcherConfig {
        max_delay: Duration::from_millis(1),
        replicas: 2,
        ..Default::default()
    })
    .landmark_config(r.landmark_config.clone())
    .backend(backend.clone());
    if let Some(cfg) = drift {
        b = b.drift(DriftHook { landmark_config: r.landmark_config.clone(), cfg });
    }
    b.build().unwrap()
}

/// Submit a batch, wait for every reply, and enforce the soak-wide
/// serving contract: no errors, no degraded replies, finite coordinates.
fn run_batch(
    h: &lmds_ose::coordinator::ServerHandle<str>,
    queries: impl IntoIterator<Item = String>,
) {
    let tickets: Vec<_> = queries
        .into_iter()
        .map(|q| h.submit(Request::object(q)))
        .collect();
    for t in tickets {
        let r = t.recv().expect("soak contract: zero error replies");
        assert!(!r.degraded, "soak contract: healthy swaps never degrade");
        assert!(r.coords.iter().all(|c| c.is_finite()));
    }
}

fn ood_query(i: usize) -> String {
    // a long different-alphabet string: far from every Geco landmark, so
    // its normalised OSE objective sits well above the calibrated
    // baseline and the drift monitor trips deterministically
    format!("qqqqqqqqqqqqqqqqqqqqqqqqqqqq{i:04}")
}

/// The headline soak: calibrate, drift, refresh exactly once, keep serving.
#[test]
fn drift_triggers_exactly_one_refresh_and_serving_stays_healthy() {
    let seed = soak_seed();
    let n = soak_n();
    let (path, names) = write_corpus("soak", seed, n);
    let pcfg = soak_pipeline(seed);
    let backend = Backend::native();
    let r = embed(&path, &pcfg, &backend);

    let drift = DriftConfig { window: 40, calibration: 40, degrade_factor: 1.3 };
    let server = start_server(&path, &r, &backend, Some(drift));
    let h = server.handle();
    let ctl = RefreshController::start(
        h.clone(),
        path.clone(),
        pcfg.clone(),
        backend.clone(),
        r.landmark_idx.clone(),
        r.landmark_config.clone(),
        RefreshConfig {
            cooldown: Duration::from_millis(400),
            ingest_buffer: 512,
            poll: Duration::from_millis(20),
        },
    )
    .unwrap();

    // Phase A — in-distribution soak: corrupted copies of corpus names
    // calibrate the monitor (40 samples) and fill the window behind it.
    let mut geco = Geco::new(GecoConfig { seed: seed ^ 0xA, ..Default::default() });
    run_batch(
        &h,
        (0..100).map(|q| geco.corrupt(&names[(q * 31) % names.len()])),
    );
    assert_eq!(h.metrics.snapshot().refreshes, 0, "no drift yet");
    assert_eq!(h.generation(), 0);

    // Phase B — OOD storm: keep injecting until the monitor fires and the
    // controller completes a refresh. Bounded, not timed: the signal is
    // deterministic, the wall clock is not.
    let t0 = Instant::now();
    let mut injected = 0usize;
    while h.metrics.snapshot().refreshes == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(120),
            "drift storm never triggered a refresh \
             (signals={}, failures={})",
            h.metrics.snapshot().drift_signals,
            h.metrics.snapshot().refresh_failures,
        );
        run_batch(&h, (0..10).map(|k| ood_query(injected + k)));
        injected += 10;
    }

    // Phase C — keep the storm going well past the cooldown: the refresh
    // consumed its signals and the post-swap monitor recalibrated on the
    // new traffic mix, so this episode fires exactly once.
    run_batch(&h, (0..80).map(|k| ood_query(10_000 + k)));

    let snap = h.metrics.snapshot();
    assert_eq!(snap.refreshes, 1, "exactly one refresh per drift episode");
    assert_eq!(snap.refresh_failures, 0);
    assert_eq!(snap.generation, 1);
    assert_eq!(h.generation(), 1);
    assert_eq!(snap.failed, 0, "zero error replies across the soak");
    assert_eq!(snap.degraded, 0);
    assert!(snap.drift_signals >= 1);

    let report = ctl.last_report().expect("a refresh completed");
    assert_eq!(report.generation, 1);
    assert!(report.ingested > 0, "the storm was ingested into the corpus");
    assert!(report.landmark_stress.is_finite());
    assert!(report.swap_drain < Duration::from_secs(30));
    assert_eq!(snap.swap_drain_ms, report.swap_drain.as_millis() as u64);
    // the alignment is either a real fit or explicitly skipped (NaN when
    // the re-selection kept fewer than dim+1 old landmarks)
    assert!(report.align_rmsd.is_nan() || report.align_rmsd >= 0.0);

    // The corpus grew by exactly the ingested queries and reopens clean.
    let table = ObjectTable::open(&path, DEFAULT_CACHE_BUDGET).unwrap();
    assert!(table.len() >= n + report.ingested);

    // Shadow-solve quality: the warm-started base must match a
    // from-scratch re-solve over the same grown corpus and landmark set
    // to within 0.05 normalised stress.
    let source = TableDelta::text(&table, &Levenshtein).unwrap();
    let new_idx = ctl.landmark_idx();
    let sub = SubsetDelta::new(&source, &new_idx);
    let mut lcfg = pcfg.lsmds.clone();
    lcfg.dim = pcfg.dim;
    lcfg.seed = pcfg.seed ^ 0x5eed;
    let (_, cold_stress) =
        solve_base_source(&sub, &lcfg, pcfg.base_solver, &backend).unwrap();
    assert!(
        (report.landmark_stress - cold_stress).abs() <= 0.05,
        "warm stress {} vs from-scratch {}",
        report.landmark_stress,
        cold_stress
    );
    drop(table);

    ctl.stop();
    drop(h);
    server.shutdown();
    std::fs::remove_file(&path).ok();
}

/// Chaos: a refresh killed between the corpus append and the shadow solve
/// must leave the old generation serving and the corpus valid — and the
/// next attempt must recover.
#[test]
fn killed_refresh_leaves_old_generation_serving_and_recovers() {
    let seed = soak_seed() ^ 0x0BAD;
    let (path, names) = write_corpus("kill", seed, 60);
    let pcfg = PipelineConfig {
        dim: 2,
        landmarks: 20,
        landmark_method: LandmarkMethod::Random,
        backend: OseBackend::Opt,
        lsmds: LsmdsConfig { dim: 2, max_iters: 60, ..Default::default() },
        ose_steps: Some(8),
        seed,
        ..Default::default()
    };
    let backend = Backend::native();
    let r = embed(&path, &pcfg, &backend);
    let server = start_server(&path, &r, &backend, None);
    let h = server.handle();
    let ctl = RefreshController::start(
        h.clone(),
        path.clone(),
        pcfg,
        backend,
        r.landmark_idx.clone(),
        r.landmark_config.clone(),
        // manual control only: the poll loop must stay out of the way
        RefreshConfig { poll: Duration::from_secs(3600), ..Default::default() },
    )
    .unwrap();

    // buffer exactly 10 queries (the tap fires at submission, so every
    // acknowledged reply is a buffered query)
    run_batch(&h, (0..10).map(|q| format!("{} x{q}", names[q])));

    ctl.set_chaos_kill(true);
    let err = ctl.run_once().expect_err("the chaos hook kills this refresh");
    assert!(err.to_string().contains("chaos"), "{err:#}");

    // old generation intact, failure counted, serving untouched
    let snap = h.metrics.snapshot();
    assert_eq!(snap.refreshes, 0);
    assert_eq!(snap.refresh_failures, 1);
    assert_eq!(snap.generation, 0);
    assert_eq!(h.generation(), 0);
    run_batch(&h, ["still serving after the kill".to_string()]);

    // the append finished before the kill: the corpus reopens valid with
    // all 10 ingested records behind the original rows
    let table = ObjectTable::open(&path, DEFAULT_CACHE_BUDGET).unwrap();
    assert_eq!(table.len(), 70);
    drop(table);

    // recovery: the next cycle completes (nothing left to ingest — the
    // killed attempt already drained the buffer into the corpus)
    ctl.set_chaos_kill(false);
    let report = ctl.run_once().unwrap();
    assert_eq!(report.generation, 1);
    assert_eq!(report.ingested, 0);
    assert_eq!(h.generation(), 1);
    assert_eq!(h.metrics.snapshot().refreshes, 1);
    run_batch(&h, ["serving on the recovered generation".to_string()]);

    ctl.stop();
    drop(h);
    server.shutdown();
    std::fs::remove_file(&path).ok();
}

/// No drift injected: replies are bit-identical across repeats, across a
/// server rebuilt from the same pipeline result (a restarted generation),
/// and across the dense (`query_k = 0`) vs graph-assisted
/// (`query_k >= L`) query paths.
#[test]
fn queries_are_bit_identical_across_restarts_and_query_k_modes() {
    let seed = soak_seed() ^ 0xB17;
    let (path, names) = write_corpus("bitid", seed, 80);
    let backend = Backend::native();
    let queries: Vec<String> = (0..12).map(|q| format!("{} probe", names[q * 5])).collect();

    let mut per_mode: Vec<Vec<Vec<f32>>> = Vec::new();
    for query_k in [0usize, 24] {
        let pcfg = PipelineConfig {
            dim: 2,
            landmarks: 24,
            landmark_method: LandmarkMethod::Random,
            backend: OseBackend::Opt,
            lsmds: LsmdsConfig { dim: 2, max_iters: 80, ..Default::default() },
            ose_steps: Some(8),
            seed,
            query_k,
            ..Default::default()
        };
        let r = embed(&path, &pcfg, &backend);
        let mut runs: Vec<Vec<Vec<f32>>> = Vec::new();
        // two servers from the same result = two serving generations of
        // the same model; two passes within each = repeat determinism
        for _ in 0..2 {
            let server = start_server(&path, &r, &backend, None);
            let h = server.handle();
            for _ in 0..2 {
                let coords: Vec<Vec<f32>> = queries
                    .iter()
                    .map(|q| {
                        let reply =
                            h.submit(Request::object(q.clone())).recv().unwrap();
                        assert!(reply.coords.iter().all(|c| c.is_finite()));
                        reply.coords
                    })
                    .collect();
                runs.push(coords);
            }
            drop(h);
            server.shutdown();
        }
        for run in &runs[1..] {
            assert_eq!(run, &runs[0], "replies drifted across runs (query_k={query_k})");
        }
        per_mode.push(runs.into_iter().next().unwrap());
    }
    assert_eq!(
        per_mode[0], per_mode[1],
        "query_k >= L must be bit-identical to the dense path"
    );
    std::fs::remove_file(&path).ok();
}
