//! Divide-and-conquer base-solver contracts: the Procrustes alignment
//! property suite (rigid motions are recovered to float precision) and the
//! partition-invariance suite (stitched stress stays within a fixed band
//! of the monolithic solve on realizable configurations, for every block
//! count the pipeline exposes). The large-L variant runs the production
//! `solve_base` path at L = 10k in release builds.

use lmds_ose::coordinator::embedder::{solve_base, BaseSolver};
use lmds_ose::mds::divide::{
    divide_solve, fps_anchors, sampled_normalized_stress, DeltaSource, DivideConfig,
    PointsDelta,
};
use lmds_ose::mds::stress::normalized_stress;
use lmds_ose::mds::{LsmdsConfig, Matrix, Procrustes};
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::euclidean;
use lmds_ose::util::prng::Rng;
use lmds_ose::util::quickcheck::{prop_assert, property};

/// Random k x k orthogonal matrix via Gram-Schmidt on a Gaussian sample;
/// `reflect` negates one column so det = -1.
fn random_orthogonal(rng: &mut Rng, k: usize, reflect: bool) -> Vec<f64> {
    let mut q = vec![0.0f64; k * k];
    for col in 0..k {
        loop {
            let mut w: Vec<f64> = (0..k).map(|_| rng.next_normal()).collect();
            for prev in 0..col {
                let mut dot = 0.0;
                for r in 0..k {
                    dot += w[r] * q[r * k + prev];
                }
                for r in 0..k {
                    w[r] -= dot * q[r * k + prev];
                }
            }
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-6 {
                for r in 0..k {
                    q[r * k + col] = w[r] / norm;
                }
                break;
            }
        }
    }
    if reflect {
        for r in 0..k {
            q[r * k] = -q[r * k];
        }
    }
    q
}

/// y_i = x_i Q + t, f64 accumulation.
fn rigid_motion(x: &Matrix, q: &[f64], t: &[f64]) -> Matrix {
    let k = x.cols;
    let mut out = Matrix::zeros(x.rows, k);
    for i in 0..x.rows {
        for j in 0..k {
            let mut acc = t[j];
            for c in 0..k {
                acc += x.at(i, c) as f64 * q[c * k + j];
            }
            out.set(i, j, acc as f32);
        }
    }
    out
}

fn realizable(seed: u64, n: usize, k: usize) -> (Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let x = Matrix::random_normal(&mut rng, n, k, 1.0);
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            d.set(i, j, euclidean(x.row(i), x.row(j)) as f32);
        }
    }
    (x, d)
}

#[test]
fn procrustes_recovers_random_rigid_motions() {
    property("procrustes recovers rotation/reflection/translation", 80, |g| {
        let k = g.usize_in(2, 8);
        let n = g.usize_in(k + 4, 40);
        let mut rng = Rng::new(g.u64());
        let x = Matrix::random_normal(&mut rng, n, k, 1.0);
        let q = random_orthogonal(&mut rng, k, g.bool());
        let t: Vec<f64> = (0..k).map(|_| rng.next_normal() * 2.0).collect();
        let y = rigid_motion(&x, &q, &t);
        let fit = Procrustes::fit(&x, &y);
        let got = fit.apply(&x);
        let diff = got.max_abs_diff(&y) as f64;
        prop_assert(diff <= 1e-5, &format!("recovery diff {diff} (n={n} k={k})"))?;
        prop_assert(fit.rmsd <= 1e-5, &format!("rmsd {}", fit.rmsd))?;
        prop_assert((fit.scale - 1.0).abs() < 1e-12, "rigid fit must not rescale")
    });
}

#[test]
fn procrustes_is_rigid_on_unseen_points() {
    // fitting on a subset and applying to the rest must preserve every
    // pairwise distance (the stitch must never distort block geometry)
    property("procrustes transforms are isometries", 40, |g| {
        let k = g.usize_in(2, 6);
        let n = g.usize_in(k + 4, 30);
        let a = g.usize_in(k + 1, n);
        let mut rng = Rng::new(g.u64());
        let x = Matrix::random_normal(&mut rng, n, k, 1.0);
        let q = random_orthogonal(&mut rng, k, g.bool());
        let t: Vec<f64> = (0..k).map(|_| rng.next_normal() * 3.0).collect();
        let anchors: Vec<usize> = (0..a).collect();
        let y_anchors = rigid_motion(&x.select_rows(&anchors), &q, &t);
        let fit = Procrustes::fit(&x.select_rows(&anchors), &y_anchors);
        let moved = fit.apply(&x);
        for i in 0..n {
            for j in (i + 1)..n {
                let before = euclidean(x.row(i), x.row(j));
                let after = euclidean(moved.row(i), moved.row(j));
                if (before - after).abs() > 1e-4 {
                    return Err(format!(
                        "distance ({i},{j}) distorted: {before} -> {after}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The fixed band of the partition-invariance contract: on realizable
/// configurations the stitched stress must land within this absolute
/// distance of the monolithic solve's stress (both are near zero there).
const STRESS_BAND: f64 = 0.05;

#[test]
fn partition_invariance_across_block_counts() {
    let (_, delta) = realizable(0xD1F, 160, 3);
    let lcfg = LsmdsConfig { dim: 3, max_iters: 2000, rel_tol: 1e-9, ..Default::default() };
    let backend = Backend::native();
    let (_, mono_stress) =
        solve_base(&delta, &lcfg, BaseSolver::Monolithic, &backend).unwrap();
    assert!(mono_stress < STRESS_BAND, "monolithic baseline itself ({mono_stress})");
    for blocks in [1usize, 2, 4, 7] {
        let (config, dc_stress) = solve_base(
            &delta,
            &lcfg,
            BaseSolver::DivideConquer { blocks, anchors: 16 },
            &backend,
        )
        .unwrap();
        assert_eq!((config.rows, config.cols), (160, 3));
        assert!(config.data.iter().all(|v| v.is_finite()), "B={blocks}");
        assert!(
            (dc_stress - mono_stress).abs() <= STRESS_BAND,
            "B={blocks}: divide stress {dc_stress} vs monolithic {mono_stress} \
             exceeds the {STRESS_BAND} band"
        );
    }
}

#[test]
fn partition_invariance_with_auto_anchors() {
    // the anchors = 0 auto heuristic must stay inside the same band
    let (_, delta) = realizable(0xD2F, 140, 2);
    let lcfg = LsmdsConfig { dim: 2, max_iters: 2000, rel_tol: 1e-9, ..Default::default() };
    let backend = Backend::native();
    let (_, mono) = solve_base(&delta, &lcfg, BaseSolver::Monolithic, &backend).unwrap();
    let (_, dc) = solve_base(
        &delta,
        &lcfg,
        BaseSolver::DivideConquer { blocks: 4, anchors: 0 },
        &backend,
    )
    .unwrap();
    assert!((dc - mono).abs() <= STRESS_BAND, "auto anchors: {dc} vs {mono}");
}

/// The L = 10k acceptance gate: the divide solve must stay within the
/// stress band of the monolithic solve through the production `solve_base`
/// path. Debug builds run the same contract at L = 1500 (the release CI
/// job covers the full scale).
#[test]
fn large_scale_divide_matches_monolithic_band() {
    let l = if cfg!(debug_assertions) { 1500 } else { 10_000 };
    let k = 3;
    let mut rng = Rng::new(0xB16);
    let points = Matrix::random_normal(&mut rng, l, k, 1.0);
    let source = PointsDelta { points: &points };
    // materialise once for the monolithic path (the divide path would not
    // need it — blocks pull sub-matrices straight from the source)
    let mut delta = Matrix::zeros(l, l);
    for i in 0..l {
        for j in (i + 1)..l {
            let d = source.dist(i, j);
            delta.set(i, j, d);
            delta.set(j, i, d);
        }
    }
    let iters = 40;
    let lcfg = LsmdsConfig { dim: k, max_iters: iters, rel_tol: 0.0, ..Default::default() };
    let backend = Backend::native();
    let (_, mono) = solve_base(&delta, &lcfg, BaseSolver::Monolithic, &backend).unwrap();
    let (config, dc) = solve_base(
        &delta,
        &lcfg,
        BaseSolver::DivideConquer { blocks: 8, anchors: 0 },
        &backend,
    )
    .unwrap();
    assert!(config.data.iter().all(|v| v.is_finite()));
    // fixed per-iteration budget: every block sweep costs ~1/B of a
    // monolithic sweep, so at equal iteration counts the divide solve has
    // done ~B x less work — it must still land in the band (in practice
    // the smaller per-block problems converge faster per iteration)
    assert!(
        dc <= mono + STRESS_BAND,
        "L={l}: divide stress {dc} vs monolithic {mono}"
    );
}

#[test]
fn matrix_free_source_agrees_with_materialised() {
    // the PointsDelta matrix-free path must give the exact same solve as
    // the materialised matrix (same anchors, same blocks, same numbers)
    let (x, delta) = realizable(0xD3F, 90, 2);
    let source = PointsDelta { points: &x };
    let lcfg = LsmdsConfig { dim: 2, max_iters: 150, ..Default::default() };
    let dcfg = DivideConfig { blocks: 3, anchors: 10 };
    let from_matrix = divide_solve(&delta, &lcfg, &dcfg).unwrap();
    let from_points = divide_solve(&source, &lcfg, &dcfg).unwrap();
    assert_eq!(from_matrix.anchor_idx, from_points.anchor_idx);
    let diff = from_matrix.config.max_abs_diff(&from_points.config);
    // both paths see f32 distances computed the same way
    assert!(diff < 1e-4, "materialised vs matrix-free diverge by {diff}");
}

#[test]
fn sampled_stress_usable_as_large_scale_metric() {
    let (x, delta) = realizable(0xD4F, 200, 3);
    let exact = normalized_stress(&x, &delta);
    let approx = sampled_normalized_stress(&delta, &x, 50_000, 7);
    assert!((exact - approx).abs() < 0.02, "exact {exact} vs sampled {approx}");
    // anchors picked by FPS must exist and be distinct at scale too
    let idx = fps_anchors(&delta, 24, 1);
    assert_eq!(idx.len(), 24);
    assert!(idx.windows(2).all(|w| w[0] < w[1]));
}
