//! Sensor-network localisation — the paper's motivating application [1]:
//! "map the sensors' locations given the pairwise distances between them
//! and then infer the locations of new targets as and when they appear."
//!
//! A jittered grid of sensors is embedded from noisy range measurements
//! (metric-space input, K = 2), then new targets are localised from their
//! ranges to the LANDMARK sensors only, via both OSE methods. Accuracy is
//! reported as true-position RMSE after Procrustes alignment.
//!
//!     cargo run --release --example sensor_network

use lmds_ose::data::synthetic::{noisy_range, sensor_grid};
use lmds_ose::mds::dissimilarity::full_matrix;
use lmds_ose::mds::landmarks::fps_landmarks;
use lmds_ose::mds::{lsmds, LsmdsConfig, Matrix};
use lmds_ose::ose::{embed_point, OseOptConfig};
use lmds_ose::strdist::{euclidean, Euclidean};
use lmds_ose::util::prng::Rng;

/// Least-squares rigid alignment (rotation+reflection+translation) of
/// `from` onto `to` via the 2-D closed-form Procrustes solution.
fn procrustes_rmse(from: &Matrix, to: &[Vec<f32>]) -> f64 {
    assert_eq!(from.rows, to.len());
    let n = from.rows as f64;
    // centroids
    let (mut fx, mut fy, mut tx, mut ty) = (0.0f64, 0.0, 0.0, 0.0);
    for i in 0..from.rows {
        fx += from.at(i, 0) as f64;
        fy += from.at(i, 1) as f64;
        tx += to[i][0] as f64;
        ty += to[i][1] as f64;
    }
    let (fx, fy, tx, ty) = (fx / n, fy / n, tx / n, ty / n);
    // cross-covariance
    let (mut sxx, mut sxy, mut syx, mut syy) = (0.0f64, 0.0, 0.0, 0.0);
    for i in 0..from.rows {
        let a = (from.at(i, 0) as f64 - fx, from.at(i, 1) as f64 - fy);
        let b = (to[i][0] as f64 - tx, to[i][1] as f64 - ty);
        sxx += a.0 * b.0;
        sxy += a.0 * b.1;
        syx += a.1 * b.0;
        syy += a.1 * b.1;
    }
    // best rotation angle (allowing reflection: test both)
    let mut best = f64::INFINITY;
    for refl in [1.0f64, -1.0] {
        let (rxx, rxy) = (sxx, sxy);
        let (ryx, ryy) = (refl * syx, refl * syy);
        let theta = (rxy - ryx).atan2(rxx + ryy);
        let (c, s) = (theta.cos(), theta.sin());
        let mut sq = 0.0f64;
        for i in 0..from.rows {
            let a = (from.at(i, 0) as f64 - fx, refl * (from.at(i, 1) as f64 - fy));
            let rot = (c * a.0 - s * a.1 + tx, s * a.0 + c * a.1 + ty);
            let d0 = rot.0 - to[i][0] as f64;
            let d1 = rot.1 - to[i][1] as f64;
            sq += d0 * d0 + d1 * d1;
        }
        best = best.min((sq / n).sqrt());
    }
    best
}

fn main() -> anyhow::Result<()> {
    lmds_ose::util::logging::init();
    let mut rng = Rng::new(0x5e25);

    // 1. ground truth: 14 x 14 sensors on the unit square
    let sensors = sensor_grid(&mut rng, 14, 0.004);
    let n = sensors.len();
    let noise = 0.03; // 3% multiplicative ranging noise

    // 2. noisy range matrix -> LSMDS map of the whole network (K = 2)
    let refs: Vec<&[f32]> = sensors.iter().map(|s| s.as_slice()).collect();
    let mut delta = full_matrix(&refs, &Euclidean);
    for i in 0..n {
        for j in (i + 1)..n {
            let d = noisy_range(&mut rng, &sensors[i], &sensors[j], noise) as f32;
            delta.set(i, j, d);
            delta.set(j, i, d);
        }
    }
    let result = lsmds(&delta, &LsmdsConfig {
        dim: 2,
        max_iters: 1200,
        rel_tol: 1e-9,
        seed: 11,
        ..Default::default()
    });
    let map_rmse = procrustes_rmse(&result.config, &sensors);
    println!(
        "network map: {n} sensors, normalized stress {:.4}, RMSE vs truth {:.4} \
         (grid pitch {:.4})",
        result.normalized_stress,
        map_rmse,
        1.0 / 14.0
    );

    // 3. landmarks = a subset of mapped sensors (anchor nodes)
    let l = 40;
    let lm_idx = fps_landmarks(&mut rng, &refs, l, &Euclidean);
    let lm_config = result.config.select_rows(&lm_idx);

    // 4. new targets appear; only their ranges to the anchors are measured
    let targets = 60;
    let mut err_opt = Vec::new();
    let cfg = OseOptConfig::default();
    let mut truths = Vec::new();
    let mut estimates = Matrix::zeros(targets, 2);
    for t in 0..targets {
        let truth = vec![rng.next_f32() * 0.9 + 0.05, rng.next_f32() * 0.9 + 0.05];
        let ranges: Vec<f32> = lm_idx
            .iter()
            .map(|&i| noisy_range(&mut rng, &sensors[i], &truth, noise) as f32)
            .collect();
        let p = embed_point(&lm_config, &ranges, None, &cfg);
        estimates.row_mut(t).copy_from_slice(&p.coords);
        truths.push(truth.clone());
        err_opt.push(p.objective);
        let _ = euclidean(&p.coords, &truth);
    }
    let target_rmse = procrustes_rmse(&estimates, &truths);
    println!(
        "target localisation: {targets} targets from {l} anchors -> RMSE {:.4} \
         (ranging noise {noise})",
        target_rmse
    );
    anyhow::ensure!(target_rmse < 0.1, "localisation degraded: {target_rmse}");
    println!("OK: new targets localised without recomputing the network map");
    Ok(())
}
