//! Entity resolution — the application domain the paper's dataset comes
//! from (Geco/FEBRL generates person records for record-linkage research;
//! the authors' future work names it explicitly).
//!
//! Duplicate detection via embedding-based *blocking*: a corpus with
//! corrupted duplicate records is embedded by the two-stage pipeline; each
//! duplicate is then treated as an unseen query, OSE-mapped, and the
//! top-k nearest candidates in the embedding are re-ranked with the exact
//! Levenshtein distance. Per query that costs L + k distance computations
//! instead of the brute-force N, with near-identical accuracy — the
//! standard blocking+verify pattern of record linkage.
//!
//!     cargo run --release --example entity_resolution

use lmds_ose::coordinator::embedder::{embed_dataset, OseBackend, PipelineConfig};
use lmds_ose::coordinator::trainer::TrainConfig;
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::cross_matrix;
use lmds_ose::mds::LsmdsConfig;
use lmds_ose::ose::OseMethod;
use lmds_ose::runtime::Backend;
use lmds_ose::strdist::{levenshtein, Levenshtein};

fn main() -> anyhow::Result<()> {
    lmds_ose::util::logging::init();

    // 1. clean corpus + corrupted duplicate queries with known ground truth
    let n = 2000;
    let n_queries = 300;
    let mut geco = Geco::new(GecoConfig { seed: 0xE5, ..Default::default() });
    let corpus = geco.generate_unique(n);
    let mut queries = Vec::with_capacity(n_queries);
    for q in 0..n_queries {
        let src = (q * 13) % n;
        let mut s = corpus[src].clone();
        for _ in 0..2 {
            s = geco.corrupt(&s);
        }
        queries.push((s, src));
    }

    // 2. embed the corpus (landmark LSMDS + NN OSE)
    let objs: Vec<&str> = corpus.iter().map(|s| s.as_str()).collect();
    let backend = Backend::auto();
    let cfg = PipelineConfig {
        dim: 7,
        landmarks: 200,
        backend: OseBackend::Nn,
        lsmds: LsmdsConfig { dim: 7, max_iters: 250, ..Default::default() },
        train: TrainConfig {
            epochs: 400,
            lr: 3e-3,
            rel_tol: 1e-5,
            patience: 20,
            ..Default::default()
        },
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let mut result = embed_dataset(&objs, &Levenshtein, &cfg, &backend)?;
    println!(
        "corpus embedded: {n} records, stress {:.4}, {:.1}s, method {}",
        result.landmark_stress,
        t0.elapsed().as_secs_f64(),
        result.method.name()
    );

    // 3. resolve each query: OSE + nearest neighbour in the embedding
    let landmark_names: Vec<&str> =
        result.landmark_idx.iter().map(|&i| objs[i]).collect();
    let qnames: Vec<&str> = queries.iter().map(|(s, _)| s.as_str()).collect();
    let t0 = std::time::Instant::now();
    let qd = cross_matrix(&qnames, &landmark_names, &Levenshtein);
    let y = result.method.embed(&qd)?;
    let top_k = 20usize;
    let mut correct_embed = 0usize; // raw top-1 in the embedding
    let mut correct_block = 0usize; // top-k blocking + exact re-rank
    let mut recall_k = 0usize; // truth inside the candidate set
    for (qi, (q, truth)) in queries.iter().enumerate() {
        // k nearest corpus points in the embedding
        let mut scored: Vec<(usize, f64)> = (0..n)
            .map(|i| {
                let mut d = 0.0f64;
                for c in 0..7 {
                    let r = (result.coords.at(i, c) - y.at(qi, c)) as f64;
                    d += r * r;
                }
                (i, d)
            })
            .collect();
        scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        if scored[0].0 == *truth {
            correct_embed += 1;
        }
        let candidates = &scored[..top_k];
        if candidates.iter().any(|(i, _)| i == truth) {
            recall_k += 1;
        }
        // verify stage: exact edit distance on the k candidates only
        let best = candidates
            .iter()
            .map(|&(i, _)| (i, levenshtein(q, &corpus[i])))
            .min_by_key(|&(_, d)| d)
            .unwrap();
        if best.0 == *truth {
            correct_block += 1;
        }
    }
    let t_embed = t0.elapsed().as_secs_f64();

    // 4. baseline: exact brute-force Levenshtein matching
    let t0 = std::time::Instant::now();
    let mut correct_exact = 0usize;
    for (q, truth) in &queries {
        let mut best = (usize::MAX, usize::MAX);
        for (i, c) in corpus.iter().enumerate() {
            let d = levenshtein(q, c);
            if d < best.1 {
                best = (i, d);
            }
        }
        if best.0 == *truth {
            correct_exact += 1;
        }
    }
    let t_exact = t0.elapsed().as_secs_f64();

    println!("---- duplicate-detection report ({n_queries} queries) ----");
    println!(
        "  embedding top-1      : {:.1}%  (no verify stage)",
        100.0 * correct_embed as f64 / n_queries as f64
    );
    println!(
        "  embedding recall@{top_k}  : {:.1}%",
        100.0 * recall_k as f64 / n_queries as f64
    );
    println!(
        "  block+verify top-1   : {:.1}%  ({:.2}s, {} + {top_k} dists/query)",
        100.0 * correct_block as f64 / n_queries as f64,
        t_embed,
        cfg.landmarks
    );
    println!(
        "  exact brute force    : {:.1}%  ({:.2}s, {n} dists/query)",
        100.0 * correct_exact as f64 / n_queries as f64,
        t_exact
    );
    println!(
        "  distance computations: {:.1}x fewer per query",
        n as f64 / (cfg.landmarks + top_k) as f64
    );
    anyhow::ensure!(
        correct_block as f64 >= 0.75 * correct_exact as f64,
        "blocking accuracy collapsed: {correct_block} vs exact {correct_exact}"
    );
    Ok(())
}
