//! Quickstart: embed a small name dataset with the two-stage pipeline and
//! map a few unseen names into the existing configuration.
//!
//!     cargo run --release --example quickstart
//!
//! Runs on the native compute backend by default; with `--features pjrt`
//! and built artifacts it uses the PJRT backend automatically.

use lmds_ose::coordinator::embedder::{embed_dataset, OseBackend, PipelineConfig};
use lmds_ose::coordinator::trainer::TrainConfig;
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::dissimilarity::cross_matrix;
use lmds_ose::mds::LsmdsConfig;
use lmds_ose::ose::OseMethod;
use lmds_ose::runtime::{Backend, ComputeBackend};
use lmds_ose::strdist::{levenshtein, Levenshtein};

fn main() -> anyhow::Result<()> {
    lmds_ose::util::logging::init();

    // 1. a "large" dataset of unique entity names (paper Sec. 5.1)
    let mut geco = Geco::new(GecoConfig { seed: 7, ..Default::default() });
    let names = geco.generate_unique(1500);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();

    // 2. two-stage pipeline: LSMDS on L=100 landmarks, NN-OSE for the rest
    let cfg = PipelineConfig {
        dim: 7,
        landmarks: 100,
        backend: OseBackend::Nn,
        lsmds: LsmdsConfig { dim: 7, max_iters: 200, ..Default::default() },
        train: TrainConfig { epochs: 300, lr: 3e-3, ..Default::default() },
        ..Default::default()
    };
    let backend = Backend::auto();
    println!("(compute backend: {})", backend.name());

    let t0 = std::time::Instant::now();
    let mut result = embed_dataset(&objs, &Levenshtein, &cfg, &backend)?;
    println!(
        "embedded {} names into 7-D in {:.2}s (landmark stress {:.4}, method {})",
        names.len(),
        t0.elapsed().as_secs_f64(),
        result.landmark_stress,
        result.method.name()
    );

    // 3. map unseen names into the EXISTING configuration (no recompute)
    let queries = ["jonh smith", "maria garcia", "xqzw blorp"];
    let landmark_names: Vec<&str> =
        result.landmark_idx.iter().map(|&i| objs[i]).collect();
    let q = cross_matrix(&queries, &landmark_names, &Levenshtein);
    let y = result.method.embed(&q)?;

    // 4. nearest neighbours in the embedding vs true edit distance
    for (qi, query) in queries.iter().enumerate() {
        let mut best = (usize::MAX, f64::INFINITY);
        for i in 0..names.len() {
            let mut d = 0.0f64;
            for c in 0..7 {
                let r = (result.coords.at(i, c) - y.at(qi, c)) as f64;
                d += r * r;
            }
            if d < best.1 {
                best = (i, d);
            }
        }
        println!(
            "query {query:?} -> nearest in embedding: {:?} (edit distance {})",
            names[best.0],
            levenshtein(query, &names[best.0])
        );
    }
    Ok(())
}
