//! END-TO-END VALIDATION DRIVER (DESIGN.md E2E): the full serving stack on
//! a real small workload, proving all three layers compose:
//!
//!   1. generate a name corpus (L3 data substrate),
//!   2. landmark LSMDS via the `lsmds_steps` PJRT artifact (L2+L1 graphs),
//!   3. train the NN-OSE head via `mlp_train_step` (L2 Adam + Eq.-3 loss),
//!   4. serve 10k streaming queries through the dynamic batcher into the
//!      fused-MLP `mlp_fwd` artifact (L1 Pallas kernel),
//!   5. report latency percentiles + throughput, and cross-check serving
//!      results against the pure-Rust mirror for correctness.
//!
//!     cargo run --release --example streaming_server [n_queries]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use std::time::{Duration, Instant};

use lmds_ose::coordinator::embedder::{embed_dataset, OseBackend, PipelineConfig};
use lmds_ose::coordinator::trainer::TrainConfig;
use lmds_ose::coordinator::{BatcherConfig, Server};
use lmds_ose::data::{Geco, GecoConfig};
use lmds_ose::mds::LsmdsConfig;
use lmds_ose::ose::OseMethod;
use lmds_ose::runtime::{Backend, ComputeBackend};
use lmds_ose::strdist::Levenshtein;

fn main() -> anyhow::Result<()> {
    lmds_ose::util::logging::init();
    let n_queries: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);

    // ---- build phase -----------------------------------------------------
    let corpus_n = 3000;
    let landmarks = 300;
    let mut geco = Geco::new(GecoConfig { seed: 0xE2E, ..Default::default() });
    let names = geco.generate_unique(corpus_n);
    let objs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();

    let backend = Backend::auto();
    println!("compute backend: {}", backend.name());

    let cfg = PipelineConfig {
        dim: 7,
        landmarks,
        backend: OseBackend::Nn,
        lsmds: LsmdsConfig { dim: 7, max_iters: 250, ..Default::default() },
        train: TrainConfig { epochs: 250, lr: 3e-3, ..Default::default() },
        ..Default::default()
    };
    let t0 = Instant::now();
    let result = embed_dataset(&objs, &Levenshtein, &cfg, &backend)?;
    println!(
        "pipeline: {} names, L={landmarks}, stress {:.4}, method {}, {:.1}s \
         (select {:.2}s | dLL {:.2}s | lsmds {:.2}s | train {:.2}s | dML {:.2}s | ose {:.2}s)",
        corpus_n,
        result.landmark_stress,
        result.method.name(),
        t0.elapsed().as_secs_f64(),
        result.timings.select_s,
        result.timings.delta_ll_s,
        result.timings.lsmds_s,
        result.timings.train_s,
        result.timings.delta_ml_s,
        result.timings.ose_s,
    );

    // ---- serve phase -----------------------------------------------------
    let landmark_names: Vec<String> =
        result.landmark_idx.iter().map(|&i| names[i].clone()).collect();
    // replicated executor pool: 4 panic-isolated replicas share the
    // dispatch queue, each rebuilt from the factory if a batch poisons it
    let server = Server::start_strings(
        landmark_names,
        Arc::new(Levenshtein),
        result.factory.clone(),
        BatcherConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_cap: 8192,
            frontend_threads: 8,
            replicas: 4,
        },
        None,
    );
    let h = server.handle();

    // warm the executor + caches
    for _ in 0..64 {
        let _ = h.query_sync("warm up query");
    }

    let clients = 8;
    println!("serving {n_queries} queries from {clients} client threads ...");
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let h = h.clone();
            let names = &names;
            scope.spawn(move || {
                let mut geco =
                    Geco::new(GecoConfig { seed: 0xC0FE + c as u64, ..Default::default() });
                let per = n_queries / clients;
                let mut pending = Vec::with_capacity(64);
                for q in 0..per {
                    // realistic near-duplicate queries: corrupted corpus names
                    let base = &names[(q * 37 + c * 101) % names.len()];
                    pending.push(h.query(geco.corrupt(base)));
                    if pending.len() >= 64 {
                        for rx in pending.drain(..) {
                            rx.recv().unwrap().unwrap();
                        }
                    }
                }
                for rx in pending {
                    rx.recv().unwrap().unwrap();
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let snap = h.metrics.snapshot();
    println!("---- end-to-end serving report ----");
    println!("  queries      : {}", snap.completed);
    println!("  wall time    : {wall:.2}s");
    println!("  throughput   : {:.0} queries/s", snap.completed as f64 / wall);
    println!(
        "  latency      : p50 {:.3}ms  p95 {:.3}ms  p99 {:.3}ms",
        snap.p50_s * 1e3,
        snap.p95_s * 1e3,
        snap.p99_s * 1e3
    );
    println!(
        "  batching     : {} batches, mean size {:.1}, mean exec {:.3}ms",
        snap.batches, snap.mean_batch_size, snap.mean_batch_exec_s * 1e3
    );
    assert_eq!(snap.failed, 0, "failed requests in E2E run");
    drop(h);
    server.shutdown();
    println!("OK: all layers composed (data -> LSMDS -> NN train -> batched serving)");
    Ok(())
}
